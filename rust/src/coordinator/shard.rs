//! The per-worker shard program: phase A (solve) → barrier → phase B
//! (duals, residuals, objectives, partial reduction) → barrier → leader
//! fold → barrier → phase C (penalty-scheme update + η publish).
//!
//! See [`super`] (the coordinator module docs) for the full schedule and
//! the determinism argument. The per-node arithmetic is the shared
//! [`crate::kernel::NodeKernel`]; this file supplies the arena-backed
//! [`SlotView`] (zero-copy parity-disciplined reads), the barrier
//! schedule, and the per-shard [`StatPartial`] reduction. Everything here
//! is crate-private; the public surface is [`super::runner::ShardedRunner`].

use std::ops::Range;
use std::sync::Mutex;

use super::arena::{ArenaScalar, ParamArena, PhaseBarrier};
use super::messages::Verdict;
use super::runner::{ShardedConfig, SolverFactory};
use crate::consensus::LocalSolver;
use crate::graph::{Graph, NodeId};
use crate::kernel::{AppMetricHook, DualPolicy, KernelScratch, NodeKernel,
                    SlotView, StopTracker};
use crate::metrics::{IterStats, Recorder, StatPartial};
use crate::util::rng::Pcg;

/// Application-metric hook threaded into the leader worker.
pub(crate) type AppMetric<'m> = &'m mut (dyn AppMetricHook + Send);

/// Why a worker stopped without a result.
#[derive(Debug)]
pub(crate) enum WorkerError {
    /// A peer poisoned the barrier (it panicked and reported separately).
    Poisoned,
    /// This worker's own body panicked (message extracted by the runner).
    Panicked(String),
}

/// Everything a worker borrows from the runner for the duration of a run.
/// Generic over the arena storage scalar (`P = f64` is the zero-copy
/// bit-parity default; `P = f32` is the reduced-precision path — see
/// [`super::runner::Precision`]).
pub(crate) struct WorkerCtx<'a, P: ArenaScalar = f64> {
    /// The (possibly relabeled) graph the pool actually runs on.
    pub graph: &'a Graph,
    pub arena: &'a ParamArena<P>,
    pub barrier: &'a PhaseBarrier,
    pub partials: &'a Mutex<Vec<ShardPartial>>,
    pub verdict: &'a Mutex<Verdict>,
    /// `order[shard_id] = original_id` — the relabeling permutation
    /// (identity when relabeling is off). Everything user-visible (solver
    /// factory, RNG streams, app-metric snapshots, reported θ) is keyed by
    /// original ids; everything pool-internal by shard ids.
    pub order: &'a [NodeId],
    pub cfg: ShardedConfig,
}

/// One shard's contribution to the leader fold, accumulated in sequential
/// node order within the shard so that combining shards in index order
/// reproduces a single-threaded sweep over `0..n`. Since the cluster
/// runtime ([`crate::cluster`]) ships the same statistics across the
/// simulated network, the type now lives in [`crate::metrics`] as
/// [`StatPartial`]; this alias keeps the coordinator's vocabulary.
pub(crate) type ShardPartial = StatPartial;

/// Leader-only state (worker 0): the shared stop state machine plus the
/// reusable θ snapshot for the app metric.
pub(crate) struct LeadState<'m> {
    tracker: StopTracker,
    metric: Option<AppMetric<'m>>,
    snapshot: Vec<Vec<f64>>,
    live: Vec<bool>,
}

impl<'m> LeadState<'m> {
    pub(crate) fn new(cfg: &ShardedConfig, dim: usize,
                      metric: Option<AppMetric<'m>>) -> LeadState<'m> {
        LeadState {
            tracker: StopTracker::new(dim, cfg.tol, cfg.patience, cfg.warmup,
                                      cfg.max_iters, cfg.params.eta0),
            metric,
            snapshot: Vec::new(),
            live: Vec::new(),
        }
    }
}

/// What the leader worker hands back to the runner.
pub(crate) struct LeadOutcome {
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
}

/// Per-node state owned by exactly one worker. θ itself lives only in the
/// arena (zero-copy); λ/η/scheme state lives in the shared protocol
/// kernel.
struct NodeState<S> {
    id: NodeId,
    solver: S,
    kernel: NodeKernel,
    /// flat η-arena index of the *incoming* penalty η_{j→i} per slot
    in_eta_idx: Vec<usize>,
}

/// The coordinator's [`SlotView`]: always-live slots, exact (lag-0)
/// reads out of the parity-disciplined arena. On the f64 path the reads
/// are zero-copy (the arena slice itself); on the f32 path each read
/// widens into `scratch` — one dim-sized buffer suffices because
/// [`SlotView`] methods take `&mut self`, so at most one returned slice
/// is live at a time.
///
/// Safety of the unsafe reads: phase A reads only parity-`theta_parity`
/// θ (no writers during the phase) and phase B reads the post-barrier
/// parity-q θ plus the stable parity-p η — the coordinator's aliasing
/// discipline, unchanged (see [`super`] module docs).
struct ArenaSlots<'a, P: ArenaScalar> {
    arena: &'a ParamArena<P>,
    nbrs: &'a [NodeId],
    theta_parity: usize,
    eta_parity: usize,
    in_eta_idx: &'a [usize],
    /// dim-sized widening buffer; untouched when `P = f64`
    scratch: &'a mut [f64],
}

impl<P: ArenaScalar> SlotView for ArenaSlots<'_, P> {
    fn live(&self, _slot: usize) -> bool {
        true
    }

    fn theta(&mut self, slot: usize) -> (&[f64], u64) {
        // Safety: see type docs.
        let raw = unsafe { self.arena.theta(self.theta_parity, self.nbrs[slot]) };
        (P::widen(raw, &mut *self.scratch), 0)
    }

    fn theta_again(&mut self, slot: usize) -> &[f64] {
        // Safety: see type docs.
        let raw = unsafe { self.arena.theta(self.theta_parity, self.nbrs[slot]) };
        P::widen(raw, &mut *self.scratch)
    }

    fn eta_in(&mut self, slot: usize) -> f64 {
        // Safety: see type docs.
        unsafe { self.arena.eta(self.eta_parity, self.in_eta_idx[slot]) }.to_f64()
    }
}

/// The worker body. `widx` is the shard index; worker 0 carries the
/// leader state. Returns the leader outcome (worker 0) or `None`.
pub(crate) fn worker_main<S: LocalSolver, P: ArenaScalar>(
    ctx: &WorkerCtx<'_, P>,
    widx: usize,
    range: Range<usize>,
    factory: SolverFactory<S>,
    mut lead: Option<LeadState<'_>>,
) -> Result<Option<LeadOutcome>, WorkerError> {
    let cfg = ctx.cfg;
    let dim = ctx.arena.dim();

    // ---- construct solvers + per-node state; publish θ⁰ / η⁰ -------------
    // solver construction and θ⁰ seeding are keyed by *original* node id
    // so a relabeled run computes exactly the same per-node trajectories
    let mut nodes: Vec<NodeState<S>> = Vec::with_capacity(range.len());
    let mut max_deg = 0usize;
    for i in range {
        let orig = ctx.order[i];
        let mut solver = factory(orig);
        assert_eq!(solver.dim(), dim, "homogeneous dims");
        let deg = ctx.graph.degree(i);
        max_deg = max_deg.max(deg);
        let mut rng = Pcg::new(cfg.seed, orig as u64 + 1);
        let theta0 = solver.initial_param(&mut rng);
        assert_eq!(theta0.len(), dim);
        let kernel = NodeKernel::new(cfg.scheme, cfg.params, deg, dim);
        // Safety: we own node i; parity 0 is the pre-loop write buffer and
        // nobody reads it before the init barrier below.
        unsafe {
            P::store(ctx.arena.theta_mut(0, i), &theta0);
            P::store(ctx.arena.eta_out_mut(0, i), &kernel.etas);
        }
        let in_eta_idx = ctx
            .graph
            .neighbors(i)
            .iter()
            .map(|&j| {
                let slot = ctx.graph.edge_slot(j, i).expect("graph symmetry");
                ctx.arena.eta_index(j, slot)
            })
            .collect();
        nodes.push(NodeState { id: i, solver, kernel, in_eta_idx });
    }
    let mut scratch = KernelScratch::new(dim, max_deg);
    let mut partial = ShardPartial::new(dim);
    // reduced-precision widening buffers, allocated once at setup. On the
    // f64 path `widen`/`write_through` never touch them (the arena slices
    // flow through directly), so the zero-copy, zero-alloc steady state
    // is preserved exactly.
    let mut own_wide = vec![0.0f64; dim];
    let mut view_wide = vec![0.0f64; dim];
    let mut write_wide = vec![0.0f64; dim];

    // everyone's θ⁰/η⁰ must be visible before the first solve
    ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?;

    for t in 0..cfg.max_iters {
        let p = t & 1; // read parity (epoch t)
        let q = p ^ 1; // write parity (epoch t+1)

        // ---- phase A: local solves on epoch-t parameters ------------------
        for st in &mut nodes {
            let NodeState { id, solver, kernel, in_eta_idx } = st;
            let id = *id;
            // Safety: phase A reads only parity-p θ (no writers this phase)
            // and writes only our own parity-q block; solve_into overwrites
            // the block in full, so stale θ^{t−1} contents are never
            // observable.
            let theta_t = P::widen(unsafe { ctx.arena.theta(p, id) },
                                   &mut own_wide);
            let mut view = ArenaSlots {
                arena: ctx.arena,
                nbrs: ctx.graph.neighbors(id),
                theta_parity: p,
                eta_parity: p,
                in_eta_idx,
                scratch: &mut view_wide,
            };
            let theta_next = unsafe { ctx.arena.theta_mut(q, id) };
            P::write_through(theta_next, &mut write_wide, |dst| {
                kernel.solve_into(solver, theta_t, ctx.graph.degree(id),
                                  &mut view, &mut scratch, dst);
            });
        }
        ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?; // epoch swap

        // ---- phase B: duals, residuals, objectives, partial reduction -----
        partial.reset();
        for st in &mut nodes {
            let NodeState { id, solver, kernel, in_eta_idx } = st;
            let id = *id;
            let deg = ctx.graph.degree(id);
            // Safety: after the barrier every parity-q θ block is complete
            // and no worker writes θ until the next phase A; η parity-p is
            // stable until phase C writes parity-q.
            let th_new = P::widen(unsafe { ctx.arena.theta(q, id) },
                                  &mut own_wide);
            let mut view = ArenaSlots {
                arena: ctx.arena,
                nbrs: ctx.graph.neighbors(id),
                theta_parity: q,
                eta_parity: p,
                in_eta_idx,
                scratch: &mut view_wide,
            };
            kernel.reduce(solver, th_new, deg, &mut view,
                          DualPolicy::exact(), &mut scratch);

            // shard-local reduction, node order = sequential order
            partial.absorb_node(kernel.f_self, kernel.primal,
                                kernel.dual, &kernel.etas, th_new);
        }
        // second shard-local pass over parity-q: spread about the *shard*
        // mean (the centered statistic the leader's Chan-style fold needs).
        // Safety: parity-q θ is stable throughout phase B.
        partial.finish_centered_with(nodes.len(), &mut scratch.nbr_mean,
                                     |absorb| {
            for st in &nodes {
                let raw = unsafe { ctx.arena.theta(q, st.id) };
                absorb(P::widen(raw, &mut own_wide));
            }
        });
        {
            let mut slots = ctx.partials.lock().unwrap_or_else(|e| e.into_inner());
            partial.store_into(&mut slots[widx]);
        }
        ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?; // stats ready

        // ---- leader fold (worker 0 only) ----------------------------------
        if let Some(lead) = lead.as_mut() {
            fold(ctx, lead, t, q);
        }
        ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?; // verdict ready

        let verdict = *ctx.verdict.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert_eq!(verdict.t, t, "verdict tag mismatch");
        if verdict.stop {
            break;
        }

        // ---- phase C: penalty-scheme updates + publish η^{t+1} ------------
        for st in &mut nodes {
            st.kernel.observe(t, (verdict.global_primal, verdict.global_dual),
                              None);
            // Safety: we own node st.id; parity-q η is the write buffer
            // until the next iteration's post-solve barrier.
            P::store(unsafe { ctx.arena.eta_out_mut(q, st.id) },
                     &st.kernel.etas);
        }
    }

    Ok(lead.map(|l| {
        let mut tracker = l.tracker;
        LeadOutcome {
            iterations: tracker.iterations,
            converged: tracker.converged,
            recorder: tracker.take_recorder(),
        }
    }))
}

/// The leader's fold: combine the W shard partials (in shard order)
/// through the shared [`StopTracker`] — the Chan-style centered
/// combination and the stop decision both live in [`crate::kernel`] now —
/// then run the app metric and publish the iteration verdict. Runs
/// between the post-stats and post-verdict barriers. O(W·dim + dim);
/// only the on-demand app-metric snapshot still reads the parity-`q`
/// arena.
fn fold<P: ArenaScalar>(ctx: &WorkerCtx<'_, P>, lead: &mut LeadState<'_>,
                        t: usize, q: usize) {
    let n = ctx.graph.len();
    let dim = ctx.arena.dim();

    let g = {
        let slots = ctx.partials.lock().unwrap_or_else(|e| e.into_inner());
        lead.tracker.round_partials(slots.iter())
    };
    debug_assert_eq!(g.folded_nodes, n, "every node folded exactly once");

    // app metric: θ materialized (into a reused snapshot) only on demand,
    // indexed by *original* node id so relabeling stays invisible
    let app_error = match lead.metric.as_mut() {
        Some(metric) => {
            if lead.snapshot.len() != n {
                lead.snapshot = vec![vec![0.0; dim]; n];
            }
            if lead.live.len() != n {
                lead.live = vec![true; n];
            }
            // Safety: between the post-stats and post-verdict barriers no
            // worker writes parity-q θ. Per-node reads (the shard-padded
            // layout has no contiguous whole-buffer view).
            for i in 0..n {
                let th = unsafe { ctx.arena.theta(q, i) };
                for (d, &x) in lead.snapshot[ctx.order[i]].iter_mut().zip(th) {
                    *d = x.to_f64();
                }
            }
            metric.measure(t, &lead.snapshot, &lead.live)
        }
        None => 0.0,
    };

    let stop = lead.tracker.commit(t, IterStats {
        iter: t,
        objective: g.objective,
        max_primal: g.max_primal,
        max_dual: g.max_dual,
        mean_eta: g.mean_eta,
        min_eta: g.min_eta,
        max_eta: g.max_eta,
        app_error,
    });
    *ctx.verdict.lock().unwrap_or_else(|e| e.into_inner()) = Verdict {
        t,
        stop,
        global_primal: g.global_primal,
        global_dual: g.global_dual,
    };
}
