//! The shared ADMM protocol kernel: **one** transcription of the paper's
//! per-node iteration, one stop state machine, one app-metric surface.
//!
//! The paper's contribution is a *protocol* — the adaptive per-edge
//! penalty update riding on the bridge-variable-eliminated consensus
//! ADMM — yet this repo grew four runtimes (sequential
//! [`crate::consensus::Engine`], sharded [`crate::coordinator`], async
//! [`crate::net`], hybrid [`crate::cluster`]) that each re-transcribed
//! the θ-solve → η̄-average → λ-step → scheme-update → residual-fold
//! sequence, with bit-parity held together only by cross-runtime tests.
//! This module collapses the duplication: runtimes now supply transport,
//! scheduling and staleness *policy*, and call here for the arithmetic,
//! so the parity contracts are consequences of shared code instead of
//! maintained coincidences — and a new λ policy or stop rule is one
//! change, not four.
//!
//! ## Method ↔ paper equation map
//!
//! | kernel method | paper | computation |
//! |---|---|---|
//! | [`NodeKernel::solve_into`] | eq. (3) primal step | `θ_i^{t+1} = argmin f_i(θ) + 2λ_iᵀθ + Σ_j η_ij ‖θ − ρ_ij‖²` via `Σ_j η_ij`, `Σ_j η_ij (θ_i + θ_j)` and [`crate::consensus::LocalSolver::solve_into`] |
//! | [`NodeKernel::reduce`] | eq. (3) dual step + eq. (5) | `λ_i += ½ Σ_j η̄_ij (θ_i^{t+1} − θ_j^{t+1})` with the edge-mean η̄_ij = ½(η_ij + η_ji); local residuals `‖r_i‖`, `‖s_i‖`; f_i at the ρ_ij bridge estimates for AP/NAP |
//! | [`NodeKernel::eta_bar`] | eq. (5) normalization | `η̄_i = Σ_j η_ij / max(deg_i, 1)` — the shared isolated-node rule (degree 0 ⇒ η̄ = 0 ⇒ zero dual residual) |
//! | [`NodeKernel::observe`] | §3 (eqs. 4, 6–12) | the masked per-node scheme update — the paper's contribution, one [`crate::penalty::PenaltyScheme`] call |
//! | [`StopTracker::round_partials`] / [`StopTracker::round_flat`] | eq. (5) global | global primal `√Σ‖θ − ḡ‖²` and dual `η⁰√n‖ḡ − ḡ_prev‖`, via Chan-combined centered partials or flat node-order sums |
//! | [`StopTracker::commit`] | §5 stop rule | relative objective-change checker (patience/warmup) + recorder + stop decision |
//!
//! ## Which runtime supplies which policy knob
//!
//! | knob | engine | coordinator | net | cluster |
//! |---|---|---|---|---|
//! | θ storage ([`SlotView`] resolution) | owned `Vec`s | arena parity block | stamp cache per slot | arena + boundary stamp cache |
//! | slot liveness ([`SlotView::live`]) | always live | always live | [`crate::graph::LiveView`] mask | machine-link mask |
//! | read staleness (lag fed to [`DualPolicy`]) | 0 | 0 | bounded by `max_staleness`, forced by `silence_timeout` | same, at machine granularity |
//! | dual policy | exact | exact | `lag_damping` / `skip_lambda_on_fallback` | exact (boundary resolution is driver-side) |
//! | fold flavour | flat, node order | partials, shard order | flat, node order | partials via tree/gossip collective |
//! | verdict transport | in-step | barrier + shared slot | omniscient fold cursor | `Verdict` messages / push-sum estimate |
//! | stop state location | the engine | leader worker 0 | fold cursor | designated machine, handed off on churn ([`StopSnapshot`]) |
//!
//! ## App metrics
//!
//! [`AppMetricHook`] is the one application-metric surface: a per-round
//! callback over `(round, θ per node in original ids, per-node liveness)`
//! whose value lands in [`crate::metrics::IterStats::app_error`]. The
//! synchronous runtimes pass all-true liveness; the async/cluster
//! runtimes pass the committed snapshot plus the live mask, so metrics
//! like the D-PPCA subspace angle run under loss and churn without
//! knowing the protocol.

mod node;
mod stop;

pub use node::{DualPolicy, KernelScratch, NodeKernel, SlotView};
pub use stop::{FlatRound, GlobalRound, StopSnapshot, StopTracker};

/// The unified application-metric surface (see module docs). Implemented
/// for any `FnMut(usize, &[Vec<f64>], &[bool]) -> f64` closure.
pub trait AppMetricHook {
    /// Observe one committed round: `(round, θ per node keyed by original
    /// id, per-node liveness)`. The return value is recorded as
    /// [`crate::metrics::IterStats::app_error`].
    fn measure(&mut self, round: usize, thetas: &[Vec<f64>], live: &[bool]) -> f64;
}

impl<F: FnMut(usize, &[Vec<f64>], &[bool]) -> f64> AppMetricHook for F {
    fn measure(&mut self, round: usize, thetas: &[Vec<f64>], live: &[bool]) -> f64 {
        self(round, thetas, live)
    }
}

#[cfg(test)]
mod golden;
