//! The per-node protocol kernel: one transcription of the paper's
//! per-iteration transitions, shared by every runtime.
//!
//! A [`NodeKernel`] owns exactly the state the *protocol* assigns to one
//! node — λ_i, the out-edge penalties η_{i→·}, the penalty-scheme
//! instance, and the residual memory — while θ stays wherever the host
//! runtime keeps it (an owned `Vec`, a parity block of the coordinator's
//! arena, …) and is passed in by reference. Neighbour access goes through
//! the [`SlotView`] trait so each runtime supplies its own *resolution*
//! (in-place slice, zero-copy arena read, stamp-indexed cache with
//! staleness accounting) without ever re-transcribing the arithmetic.
//!
//! Bit-parity contract: for a fully live neighbourhood with exact (lag-0)
//! reads, every method reproduces the pre-refactor `Engine::step`
//! floating-point stream exactly — same loops, same accumulation order,
//! same parenthesization. The golden-trace tests in [`super::golden`]
//! pin this at the kernel boundary for all seven schemes.

use crate::consensus::LocalSolver;
use crate::penalty::{make_scheme, NodeObservation, PenaltyScheme, SchemeKind,
                     SchemeParams};

/// Per-phase view of one node's neighbourhood, supplied by the runtime.
///
/// The kernel dictates *what* is read (which slots, in slot order, at
/// which point of the arithmetic); the implementation dictates *how*
/// (direct slice, arena parity block, bounded-staleness cache) and owns
/// any staleness accounting side effects, which must happen inside
/// [`SlotView::theta`] / [`SlotView::eta_in`] so counters and traces
/// keep their pre-refactor order.
pub trait SlotView {
    /// Whether the slot participates in this phase (synchronous runtimes:
    /// always true; dynamic topologies: the live mask).
    fn live(&self, slot: usize) -> bool;

    /// Resolve the slot's θ at this phase's ideal stamp, with the
    /// runtime's staleness accounting. Returns the parameter slice and
    /// the read's lag in rounds (0 = exact; synchronous runtimes always
    /// return 0).
    fn theta(&mut self, slot: usize) -> (&[f64], u64);

    /// Re-touch the θ already resolved by [`SlotView::theta`] this phase
    /// (the ρ-midpoint pass) — no staleness accounting.
    fn theta_again(&mut self, slot: usize) -> &[f64];

    /// Resolve the slot's incoming penalty η_{j→i} at this phase's ideal
    /// stamp (phase B only), with accounting.
    fn eta_in(&mut self, slot: usize) -> f64;
}

/// How the dual step treats reads that resolved stale — the one-line
/// policy layer on top of the kernel (both shipped policies are
/// bit-transparent whenever every read is exact):
///
/// * `lag_damping` — scale a slot's λ increment by `1/(1+lag)`
///   ([`crate::net::NetConfig::lag_damping`]): stale dual steps are the
///   positive feedback behind the staleness ≥ 2 divergence, and damping
///   shrinks exactly those steps.
/// * `skip_beyond` — drop the λ increment entirely for reads past the
///   staleness budget (the forced silent-neighbour fallback,
///   [`crate::net::NetConfig::skip_lambda_on_fallback`]): a fallback
///   read's generation mismatch is unbounded, so its dual step carries
///   more noise than signal. The θ still feeds the neighbour mean — only
///   the multiplier is protected.
///
/// The two compose: with both enabled, fallback reads are skipped and
/// within-budget stale reads are damped.
#[derive(Debug, Clone, Copy, Default)]
pub struct DualPolicy {
    /// Scale stale λ increments by `1/(1 + lag)`.
    pub lag_damping: bool,
    /// Skip the λ increment when `lag > budget` (a forced fallback read).
    pub skip_beyond: Option<u64>,
}

impl DualPolicy {
    /// The synchronous runtimes' policy: every read is exact, so both
    /// knobs are inert — kept explicit for the call sites' readability.
    pub fn exact() -> DualPolicy {
        DualPolicy::default()
    }
}

/// Worker- or engine-level scratch reused across nodes and iterations
/// (the hot loop allocates nothing in steady state).
pub struct KernelScratch {
    /// Σ_j η_ij (θ_i + θ_j), accumulated per solve
    pub eta_wsum: Vec<f64>,
    /// neighbour mean θ̄_i, accumulated per reduce
    pub nbr_mean: Vec<f64>,
    /// ρ_ij midpoint buffers, sized to the max degree served
    pub rhos: Vec<Vec<f64>>,
}

impl KernelScratch {
    pub fn new(dim: usize, max_deg: usize) -> KernelScratch {
        KernelScratch {
            eta_wsum: vec![0.0; dim],
            nbr_mean: vec![0.0; dim],
            rhos: vec![vec![0.0; dim]; max_deg],
        }
    }
}

/// One node's protocol state and transitions (see module docs and the
/// equation map in [`super`]).
pub struct NodeKernel {
    /// the paper's contribution: the per-node penalty scheduler
    pub scheme: Box<dyn PenaltyScheme>,
    /// out-edge penalties η_{i→j}, neighbour-slot order (the working
    /// copy; arena-based runtimes publish it after phase C)
    pub etas: Vec<f64>,
    /// the multiplier λ_i
    pub lambda: Vec<f64>,
    /// previous neighbour mean (dual-residual memory, paper eq. 5)
    pub nbr_mean_prev: Vec<f64>,
    /// f_i at the ρ_ij bridge estimates (AP/NAP), slot order
    pub f_nb: Vec<f64>,
    pub f_self_prev: f64,
    // -- carried from solve to reduce/observe within one iteration --------
    /// Σ_j η_ij over the slots live at phase A
    pub eta_sum: f64,
    /// live-slot count at phase A — η̄ must divide the phase-A η sum by
    /// the phase-A degree even if liveness changes mid-round
    pub live_deg_a: usize,
    pub f_self: f64,
    /// ‖r_i‖ (local primal residual norm)
    pub primal: f64,
    /// ‖s_i‖ (local dual residual norm)
    pub dual: f64,
}

impl NodeKernel {
    /// Protocol state for one node of the given degree: η⁰ on every
    /// slot, λ = 0, and a fresh scheme instance.
    pub fn new(kind: SchemeKind, params: SchemeParams, deg: usize, dim: usize)
               -> NodeKernel {
        NodeKernel {
            scheme: make_scheme(kind, params, deg),
            etas: vec![params.eta0; deg],
            lambda: vec![0.0; dim],
            nbr_mean_prev: vec![0.0; dim],
            f_nb: vec![0.0; deg],
            f_self_prev: f64::INFINITY,
            eta_sum: 0.0,
            live_deg_a: 0,
            f_self: 0.0,
            primal: 0.0,
            dual: 0.0,
        }
    }

    /// Whether this node's scheme scores neighbour estimates (AP/NAP).
    pub fn needs_neighbor_objectives(&self) -> bool {
        self.scheme.needs_neighbor_objectives()
    }

    /// Whether this node's scheme reads folded global residuals (RB) —
    /// the runtime must then gate phase C on the round's verdict.
    pub fn needs_global_residuals(&self) -> bool {
        self.scheme.needs_global_residuals()
    }

    /// The node-mean penalty η̄_i = (Σ_j η_ij) / deg with the shared
    /// isolated-node rule: the divisor is `max(live degree at phase A, 1)`,
    /// so a degree-0 node gets η̄ = 0 (and hence a zero dual residual) in
    /// every runtime identically.
    pub fn eta_bar(&self) -> f64 {
        self.eta_sum * (1.0 / self.live_deg_a.max(1) as f64)
    }

    /// **Phase A** — the penalized local solve (paper eq. alignment in
    /// [`super`]): accumulate `Σ_j η_ij` and `Σ_j η_ij (θ_i + θ_j)` over
    /// the live slots in slot order, then hand the argmin to the solver,
    /// landing θ_i^{t+1} in `out` (an arena block or an owned buffer —
    /// the solver's `solve_into` contract keeps it allocation-free).
    pub fn solve_into<S: LocalSolver + ?Sized>(
        &mut self,
        solver: &mut S,
        theta_t: &[f64],
        deg: usize,
        view: &mut dyn SlotView,
        scratch: &mut KernelScratch,
        out: &mut [f64],
    ) {
        let dim = theta_t.len();
        let mut eta_sum = 0.0;
        let mut live_deg = 0usize;
        scratch.eta_wsum.iter_mut().for_each(|x| *x = 0.0);
        for slot in 0..deg {
            if !view.live(slot) {
                continue;
            }
            live_deg += 1;
            let e = self.etas[slot];
            eta_sum += e;
            let (tj, _) = view.theta(slot);
            for k in 0..dim {
                scratch.eta_wsum[k] += e * (theta_t[k] + tj[k]);
            }
        }
        self.eta_sum = eta_sum;
        self.live_deg_a = live_deg;
        solver.solve_into(theta_t, &self.lambda, eta_sum, &scratch.eta_wsum, out);
    }

    /// **Phase B** — the round-`t` reduce: the symmetrized dual step
    /// `λ_i += ½ Σ_j η̄_ij (θ_i − θ_j)` fused with the neighbour-mean
    /// accumulation (independent accumulators, each fed in slot order —
    /// the fusion never changes a per-accumulator floating-point
    /// grouping), then the local residuals (paper eq. 5) and the
    /// objective evaluations the scheme will observe in phase C.
    ///
    /// `theta_new` is θ_i^{t+1}; the view resolves neighbour θ^{t+1} and
    /// incoming η^t. Results land in [`NodeKernel::primal`] /
    /// [`NodeKernel::dual`] / [`NodeKernel::f_self`] / [`NodeKernel::f_nb`].
    pub fn reduce<S: LocalSolver + ?Sized>(
        &mut self,
        solver: &mut S,
        theta_new: &[f64],
        deg: usize,
        view: &mut dyn SlotView,
        policy: DualPolicy,
        scratch: &mut KernelScratch,
    ) {
        let dim = theta_new.len();

        // ---- dual step + neighbour mean, slot order ----------------------
        scratch.nbr_mean.iter_mut().for_each(|x| *x = 0.0);
        let mut live_deg = 0usize;
        for slot in 0..deg {
            if !view.live(slot) {
                continue;
            }
            live_deg += 1;
            let eta_in = view.eta_in(slot);
            let eta_bar = 0.5 * (self.etas[slot] + eta_in);
            let (tj, lag) = view.theta(slot);
            if policy.skip_beyond.is_some_and(|budget| lag > budget) {
                // skip-λ-on-fallback: the θ still feeds the mean
                for k in 0..dim {
                    scratch.nbr_mean[k] += tj[k];
                }
            } else if policy.lag_damping && lag > 0 {
                let damp = 1.0 / (1.0 + lag as f64);
                for k in 0..dim {
                    self.lambda[k] += damp * (0.5 * eta_bar * (theta_new[k] - tj[k]));
                    scratch.nbr_mean[k] += tj[k];
                }
            } else {
                // the exact-read branch is kept verbatim so the default
                // is literally the pre-policy arithmetic
                for k in 0..dim {
                    self.lambda[k] += 0.5 * eta_bar * (theta_new[k] - tj[k]);
                    scratch.nbr_mean[k] += tj[k];
                }
            }
        }

        // ---- local residuals (paper eq. 5) -------------------------------
        // The mean divides by the phase-B live count (it must match the
        // sum just accumulated) while η̄ divides the phase-A η sum by the
        // phase-A count — mid-round liveness changes must not pair one
        // snapshot's sum with the other's degree. At a stable topology
        // both counts are equal.
        let inv_deg = 1.0 / live_deg.max(1) as f64;
        scratch.nbr_mean.iter_mut().for_each(|x| *x *= inv_deg);
        let eta_bar_node = self.eta_bar();
        let mut r2 = 0.0;
        let mut s2 = 0.0;
        for k in 0..dim {
            let r = theta_new[k] - scratch.nbr_mean[k];
            let s = eta_bar_node * (scratch.nbr_mean[k] - self.nbr_mean_prev[k]);
            r2 += r * r;
            s2 += s * s;
        }
        self.nbr_mean_prev.copy_from_slice(&scratch.nbr_mean);
        self.primal = r2.sqrt();
        self.dual = s2.sqrt();

        // ---- objectives (f at the ρ bridge midpoints only if the scheme
        // asks; dead slots get a placeholder the scheme's mask excludes) --
        self.f_self = solver.objective(theta_new);
        if self.scheme.needs_neighbor_objectives() {
            for slot in 0..deg {
                let rho = &mut scratch.rhos[slot];
                if view.live(slot) {
                    let tj = view.theta_again(slot);
                    for k in 0..dim {
                        rho[k] = 0.5 * (theta_new[k] + tj[k]);
                    }
                } else {
                    rho.copy_from_slice(theta_new);
                }
            }
            solver.objective_batch_into(&scratch.rhos[..deg], &mut self.f_nb);
        } else {
            self.f_nb.clear();
            self.f_nb.resize(deg, 0.0);
        }
    }

    /// **Phase C** — the masked scheme update (the paper's contribution):
    /// build the [`NodeObservation`] from this round's reduce products
    /// and the runtime-supplied global residual verdict, let the scheme
    /// rewrite η in place, and roll the objective memory forward.
    ///
    /// `live = None` (what synchronous runtimes pass for a fully live
    /// neighbourhood) is bit-identical to the pre-liveness behaviour.
    pub fn observe(&mut self, t: usize, globals: (f64, f64), live: Option<&[bool]>) {
        let obs = NodeObservation {
            t,
            primal_norm: self.primal,
            dual_norm: self.dual,
            global_primal: globals.0,
            global_dual: globals.1,
            f_self: self.f_self,
            f_self_prev: self.f_self_prev,
            f_neighbors: &self.f_nb,
            live,
        };
        self.scheme.update(&obs, &mut self.etas);
        self.f_self_prev = self.f_self;
    }
}
