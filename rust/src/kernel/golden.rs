//! Golden-trace property tests: the kernel transitions are bit-identical
//! to the **pre-refactor** `Engine::step`.
//!
//! [`GoldenEngine`] below is a frozen, verbatim transcription of the
//! sequential engine as it existed before the kernel extraction (PR 5) —
//! separate λ/η/scheme vectors, the un-fused dual and residual passes,
//! the flat global fold, the trailing scheme-update pass. It is test-only
//! reference code and must never be "cleaned up" to call the kernel: its
//! whole value is being an independent transcription of the same
//! arithmetic. The tests drive it in lock-step with the kernel-backed
//! [`Engine`] on seeded Ring/Star problems for all seven schemes and
//! assert θ, λ, η and every recorded statistic equal **to the bit** at
//! every iteration — pinning the refactor's parity at the kernel
//! boundary instead of only end-to-end.

use crate::consensus::solvers::QuadraticNode;
use crate::consensus::{Engine, EngineConfig, LocalSolver};
use crate::graph::{Graph, Topology};
use crate::metrics::IterStats;
use crate::penalty::{make_scheme, NodeObservation, PenaltyScheme, SchemeKind,
                     SchemeParams};
use crate::util::rng::Pcg;

/// The pre-refactor engine, frozen (see module docs).
struct GoldenEngine<S: LocalSolver> {
    graph: Graph,
    solvers: Vec<S>,
    cfg: EngineConfig,
    thetas: Vec<Vec<f64>>,
    lambdas: Vec<Vec<f64>>,
    etas: Vec<Vec<f64>>,
    schemes: Vec<Box<dyn PenaltyScheme>>,
    rev_slot: Vec<Vec<usize>>,
    nbr_mean_prev: Vec<Vec<f64>>,
    global_mean_prev: Vec<f64>,
    f_self_prev: Vec<f64>,
    scratch_new_thetas: Vec<Vec<f64>>,
    scratch_eta_wsum: Vec<f64>,
    scratch_rhos: Vec<Vec<f64>>,
    scratch_eta_sums: Vec<f64>,
    scratch_nbr_mean: Vec<f64>,
    scratch_global_mean: Vec<f64>,
    scratch_primal_norms: Vec<f64>,
    scratch_dual_norms: Vec<f64>,
    scratch_f_self: Vec<f64>,
    scratch_f_nb: Vec<f64>,
}

impl<S: LocalSolver> GoldenEngine<S> {
    fn new(graph: Graph, mut solvers: Vec<S>, cfg: EngineConfig) -> Self {
        assert_eq!(graph.len(), solvers.len());
        let dim = solvers[0].dim();
        let mut rng = Pcg::new(cfg.seed, 0xE191E);
        let thetas: Vec<Vec<f64>> = solvers
            .iter_mut()
            .map(|s| s.initial_param(&mut rng))
            .collect();
        let n = graph.len();
        let schemes = (0..n)
            .map(|i| make_scheme(cfg.scheme, cfg.params, graph.degree(i)))
            .collect();
        let etas = (0..n)
            .map(|i| vec![cfg.params.eta0; graph.degree(i)])
            .collect();
        let rev_slot = (0..n)
            .map(|i| {
                graph
                    .neighbors(i)
                    .iter()
                    .map(|&j| graph.edge_slot(j, i).expect("graph symmetry"))
                    .collect()
            })
            .collect();
        let max_deg = (0..n).map(|i| graph.degree(i)).max().unwrap_or(0);
        GoldenEngine {
            rev_slot,
            lambdas: vec![vec![0.0; dim]; n],
            nbr_mean_prev: vec![vec![0.0; dim]; n],
            global_mean_prev: vec![0.0; dim],
            f_self_prev: vec![f64::INFINITY; n],
            scratch_new_thetas: vec![vec![0.0; dim]; n],
            scratch_eta_wsum: vec![0.0; dim],
            scratch_rhos: vec![vec![0.0; dim]; max_deg],
            scratch_eta_sums: vec![0.0; n],
            scratch_nbr_mean: vec![0.0; dim],
            scratch_global_mean: vec![0.0; dim],
            scratch_primal_norms: vec![0.0; n],
            scratch_dual_norms: vec![0.0; n],
            scratch_f_self: vec![0.0; n],
            scratch_f_nb: Vec::with_capacity(max_deg),
            etas,
            schemes,
            thetas,
            solvers,
            graph,
            cfg,
        }
    }

    /// Verbatim pre-refactor `Engine::step`.
    fn step(&mut self, t: usize) -> IterStats {
        let n = self.graph.len();
        let dim = self.thetas[0].len();

        for i in 0..n {
            let mut eta_sum = 0.0;
            self.scratch_eta_wsum.iter_mut().for_each(|x| *x = 0.0);
            for (slot, &j) in self.graph.neighbors(i).iter().enumerate() {
                let eta = self.etas[i][slot];
                eta_sum += eta;
                let ti = &self.thetas[i];
                let tj = &self.thetas[j];
                for k in 0..dim {
                    self.scratch_eta_wsum[k] += eta * (ti[k] + tj[k]);
                }
            }
            self.scratch_eta_sums[i] = eta_sum;
            self.solvers[i].solve_into(
                &self.thetas[i], &self.lambdas[i], eta_sum,
                &self.scratch_eta_wsum, &mut self.scratch_new_thetas[i]);
        }

        std::mem::swap(&mut self.thetas, &mut self.scratch_new_thetas);

        for i in 0..n {
            for (slot, &j) in self.graph.neighbors(i).iter().enumerate() {
                let eta = 0.5 * (self.etas[i][slot] + self.etas[j][self.rev_slot[i][slot]]);
                let (ti, tj) = (&self.thetas[i], &self.thetas[j]);
                let li = &mut self.lambdas[i];
                for k in 0..dim {
                    li[k] += 0.5 * eta * (ti[k] - tj[k]);
                }
            }
        }

        let mut max_primal: f64 = 0.0;
        let mut max_dual: f64 = 0.0;
        for i in 0..n {
            let inv_deg = 1.0 / self.graph.degree(i).max(1) as f64;
            self.scratch_nbr_mean.iter_mut().for_each(|x| *x = 0.0);
            for &j in self.graph.neighbors(i) {
                for k in 0..dim {
                    self.scratch_nbr_mean[k] += self.thetas[j][k];
                }
            }
            self.scratch_nbr_mean.iter_mut().for_each(|x| *x *= inv_deg);
            let eta_bar = self.scratch_eta_sums[i] * inv_deg;
            let mut r2 = 0.0;
            let mut s2 = 0.0;
            for k in 0..dim {
                let r = self.thetas[i][k] - self.scratch_nbr_mean[k];
                let s = eta_bar * (self.scratch_nbr_mean[k] - self.nbr_mean_prev[i][k]);
                r2 += r * r;
                s2 += s * s;
            }
            self.scratch_primal_norms[i] = r2.sqrt();
            self.scratch_dual_norms[i] = s2.sqrt();
            max_primal = max_primal.max(self.scratch_primal_norms[i]);
            max_dual = max_dual.max(self.scratch_dual_norms[i]);
            self.nbr_mean_prev[i].copy_from_slice(&self.scratch_nbr_mean);
        }

        self.scratch_global_mean.iter_mut().for_each(|x| *x = 0.0);
        for th in &self.thetas {
            for k in 0..dim {
                self.scratch_global_mean[k] += th[k];
            }
        }
        self.scratch_global_mean.iter_mut().for_each(|x| *x /= n as f64);
        let mut gr2 = 0.0;
        for th in &self.thetas {
            for k in 0..dim {
                let d = th[k] - self.scratch_global_mean[k];
                gr2 += d * d;
            }
        }
        let mut gs2 = 0.0;
        for k in 0..dim {
            let d = self.scratch_global_mean[k] - self.global_mean_prev[k];
            gs2 += d * d;
        }
        let eta_global = self.cfg.params.eta0;
        let global_primal = gr2.sqrt();
        let global_dual = eta_global * (n as f64).sqrt() * gs2.sqrt();
        self.global_mean_prev.copy_from_slice(&self.scratch_global_mean);

        let mut objective = 0.0;
        for i in 0..n {
            let f = self.solvers[i].objective(&self.thetas[i]);
            self.scratch_f_self[i] = f;
            objective += f;
        }

        let (mut min_eta, mut max_eta, mut sum_eta, mut cnt) =
            (f64::INFINITY, 0.0f64, 0.0, 0usize);
        for e in self.etas.iter().flatten() {
            min_eta = min_eta.min(*e);
            max_eta = max_eta.max(*e);
            sum_eta += *e;
            cnt += 1;
        }

        for i in 0..n {
            self.scratch_f_nb.clear();
            if self.schemes[i].needs_neighbor_objectives() {
                let deg = self.graph.degree(i);
                for (slot, &j) in self.graph.neighbors(i).iter().enumerate() {
                    let rho = &mut self.scratch_rhos[slot];
                    for k in 0..dim {
                        rho[k] = 0.5 * (self.thetas[i][k] + self.thetas[j][k]);
                    }
                }
                self.solvers[i]
                    .objective_batch_into(&self.scratch_rhos[..deg], &mut self.scratch_f_nb);
            } else {
                self.scratch_f_nb.resize(self.graph.degree(i), 0.0);
            }
            let obs = NodeObservation {
                t,
                primal_norm: self.scratch_primal_norms[i],
                dual_norm: self.scratch_dual_norms[i],
                global_primal,
                global_dual,
                f_self: self.scratch_f_self[i],
                f_self_prev: self.f_self_prev[i],
                f_neighbors: &self.scratch_f_nb,
                live: None,
            };
            self.schemes[i].update(&obs, &mut self.etas[i]);
            self.f_self_prev[i] = self.scratch_f_self[i];
        }

        IterStats {
            iter: t,
            objective,
            max_primal,
            max_dual,
            mean_eta: if cnt == 0 { 0.0 } else { sum_eta / cnt as f64 },
            min_eta: if cnt == 0 { 0.0 } else { min_eta },
            max_eta,
            app_error: 0.0,
        }
    }
}

// ---------------------------------------------------------------------------

fn quad_nodes(n: usize, dim: usize, seed: u64) -> Vec<QuadraticNode> {
    let mut rng = Pcg::seed(seed);
    (0..n).map(|_| QuadraticNode::random(dim, &mut rng)).collect()
}

fn assert_stats_bits(a: &IterStats, b: &IterStats, ctx: &str) {
    assert_eq!(a.iter, b.iter, "{ctx}");
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{ctx} objective");
    assert_eq!(a.max_primal.to_bits(), b.max_primal.to_bits(), "{ctx} max_primal");
    assert_eq!(a.max_dual.to_bits(), b.max_dual.to_bits(), "{ctx} max_dual");
    assert_eq!(a.mean_eta.to_bits(), b.mean_eta.to_bits(), "{ctx} mean_eta");
    assert_eq!(a.min_eta.to_bits(), b.min_eta.to_bits(), "{ctx} min_eta");
    assert_eq!(a.max_eta.to_bits(), b.max_eta.to_bits(), "{ctx} max_eta");
}

/// Drive the kernel-backed engine and the golden pre-refactor engine in
/// lock-step and diff the full per-node state bitwise every iteration.
fn assert_golden_parity(graph: Graph, scheme: SchemeKind, seed: u64,
                        data_seed: u64, iters: usize, ctx: &str) {
    let n = graph.len();
    let dim = 3;
    let cfg = EngineConfig { scheme, tol: 0.0, max_iters: iters, seed,
                             ..Default::default() };
    let mut engine = Engine::new(graph.clone(), quad_nodes(n, dim, data_seed), cfg);
    let mut golden = GoldenEngine::new(graph, quad_nodes(n, dim, data_seed), cfg);

    assert_eq!(engine.thetas(), &golden.thetas[..], "{ctx}: θ⁰ seeding");
    for t in 0..iters {
        let a = engine.step(t, &mut |_, _| 0.0);
        let b = golden.step(t);
        let ctx = format!("{ctx} iter {t}");
        assert_stats_bits(&a, &b, &ctx);
        assert_eq!(engine.thetas(), &golden.thetas[..], "{ctx}: θ");
        for i in 0..n {
            assert_eq!(engine.kernels[i].lambda, golden.lambdas[i], "{ctx}: λ[{i}]");
            assert_eq!(engine.kernels[i].etas, golden.etas[i], "{ctx}: η[{i}]");
            assert_eq!(engine.kernels[i].nbr_mean_prev, golden.nbr_mean_prev[i],
                       "{ctx}: θ̄_prev[{i}]");
        }
    }
}

#[test]
fn kernel_golden_trace_ring_all_schemes() {
    // the satellite bar: NodeKernel transitions ≡ pre-refactor
    // Engine::step bit-for-bit, every scheme, on the sparse cycle
    for scheme in SchemeKind::ALL {
        assert_golden_parity(Topology::Ring.build(6).unwrap(), scheme, 11, 5,
                             30, &format!("ring/{scheme:?}"));
    }
}

#[test]
fn kernel_golden_trace_star_all_schemes() {
    // ... and on the hub topology (heterogeneous degrees: the η̄ and
    // rev-slot paths see asymmetric neighbourhoods)
    for scheme in SchemeKind::ALL {
        assert_golden_parity(Topology::Star.build(6).unwrap(), scheme, 23, 9,
                             30, &format!("star/{scheme:?}"));
    }
}

#[test]
fn kernel_golden_trace_seed_sweep() {
    // property flavour: a seed sweep over (topology, scheme, seed) cells
    // on the adaptive schemes, so the parity claim is not one lucky seed
    for (s, scheme) in [(1u64, SchemeKind::Ap), (2, SchemeKind::Nap),
                        (3, SchemeKind::VpAp), (4, SchemeKind::Rb),
                        (5, SchemeKind::VpNap)] {
        for topo in [Topology::Ring, Topology::Star] {
            assert_golden_parity(topo.build(5).unwrap(), scheme, s, 100 + s,
                                 20, &format!("{topo:?}/{scheme:?}/seed{s}"));
        }
    }
}

#[test]
fn kernel_golden_trace_isolated_node() {
    // degree-0 node: the shared η̄ = 0 isolated-node rule must hold at
    // the kernel boundary too
    for scheme in SchemeKind::ALL {
        assert_golden_parity(Graph::new(1, &[]).unwrap(), scheme, 9, 9, 15,
                             &format!("isolated/{scheme:?}"));
    }
}
