//! The shared stop state machine: per-round global-statistics fold →
//! primal/dual residual verdict → recorder/convergence commit.
//!
//! Two fold *flavours* feed the same commit path, preserving each
//! runtime family's exact floating-point stream:
//!
//! * **partials** ([`StopTracker::round_partials`]) — the sharded
//!   coordinator's leader and the cluster tree root absorb per-shard
//!   centered [`StatPartial`]s in shard order with the Chan-style
//!   [`RunningFold`] (O(W·dim), accurate at any ‖θ‖ scale);
//! * **flat** ([`FlatRound`] + [`StopTracker::round_flat`]) — the
//!   sequential engine and the async per-node runtime accumulate flat
//!   sums over whole-node contributions in node-id order (the oracle
//!   arithmetic the zero-fault parity tests diff against).
//!
//! Both flavours derive the verdict identically: global primal
//! `√Σ‖θ − ḡ‖²`, global dual `η⁰ √n ‖ḡ − ḡ_prev‖` with ḡ_prev starting
//! at zero (bit-equal to the legacy `Option<Vec>`/`None` handling, since
//! `(a − 0)² ≡ a·a` in IEEE arithmetic), and
//! [`StopTracker::commit`] runs the one relative-change
//! [`ConvergenceChecker`] + [`Recorder`] + stop decision every runtime
//! used to re-implement.
//!
//! The whole tracker state is serializable ([`StopTracker::snapshot`] /
//! [`StopTracker::resume`]) so the cluster runtime can hand the
//! checker/recorder duty over the simulated network on leader churn
//! instead of migrating it omnisciently.

use crate::metrics::{CheckerState, ConvergenceChecker, IterStats, Recorder,
                     RunningFold, StatPartial};

/// One round's folded global statistics — the verdict the RB scheme and
/// the stop rule consume, plus the recorder-facing aggregates.
#[derive(Debug, Clone, Copy)]
pub struct GlobalRound {
    /// Σ_i f_i(θ_i)
    pub objective: f64,
    /// √Σ‖θ − ḡ‖² — the global primal residual
    pub global_primal: f64,
    /// η⁰ √n ‖ḡ − ḡ_prev‖ — the global dual residual
    pub global_dual: f64,
    pub max_primal: f64,
    pub max_dual: f64,
    pub mean_eta: f64,
    pub min_eta: f64,
    pub max_eta: f64,
    /// nodes folded into this round
    pub folded_nodes: usize,
}

/// Flat per-round accumulator (the engine/async flavour): every
/// statistic is a plain sum/max over whole-node contributions, fed in
/// node-id order, with the mean divided (not reciprocal-multiplied) —
/// the sequential engine's exact arithmetic.
#[derive(Debug, Clone)]
pub struct FlatRound {
    pub objective: f64,
    pub max_primal: f64,
    pub max_dual: f64,
    pub min_eta: f64,
    pub max_eta: f64,
    pub sum_eta: f64,
    pub eta_count: usize,
    /// Σθ during accumulation; the mean after [`FlatRound::finish_mean`]
    pub gmean: Vec<f64>,
    /// contributions folded (the divisor for the mean)
    pub count: usize,
    /// Σ‖θ − ḡ‖², accumulated by [`FlatRound::add_spread`]
    pub gr2: f64,
}

impl FlatRound {
    pub fn new(dim: usize) -> FlatRound {
        FlatRound {
            objective: 0.0,
            max_primal: 0.0,
            max_dual: 0.0,
            min_eta: f64::INFINITY,
            max_eta: 0.0,
            sum_eta: 0.0,
            eta_count: 0,
            gmean: vec![0.0; dim],
            count: 0,
            gr2: 0.0,
        }
    }

    /// Zero every accumulator for a new round.
    pub fn begin(&mut self) {
        self.objective = 0.0;
        self.max_primal = 0.0;
        self.max_dual = 0.0;
        self.min_eta = f64::INFINITY;
        self.max_eta = 0.0;
        self.sum_eta = 0.0;
        self.eta_count = 0;
        self.gmean.iter_mut().for_each(|x| *x = 0.0);
        self.count = 0;
        self.gr2 = 0.0;
    }

    /// Fold one node's scalar statistics (objective, residual norms, the
    /// η stream over its out-edges).
    pub fn add_node(&mut self, f_self: f64, primal: f64, dual: f64, etas: &[f64]) {
        self.objective += f_self;
        self.max_primal = self.max_primal.max(primal);
        self.max_dual = self.max_dual.max(dual);
        for &e in etas {
            self.min_eta = self.min_eta.min(e);
            self.max_eta = self.max_eta.max(e);
            self.sum_eta += e;
        }
        self.eta_count += etas.len();
    }

    /// Accumulate one node's θ into the global sum.
    pub fn add_theta(&mut self, theta: &[f64]) {
        for (k, &x) in theta.iter().enumerate() {
            self.gmean[k] += x;
        }
        self.count += 1;
    }

    /// Turn the θ sum into the mean (plain division — parity-critical).
    pub fn finish_mean(&mut self) {
        let n = self.count as f64;
        self.gmean.iter_mut().for_each(|x| *x /= n);
    }

    /// Second pass: accumulate one node's spread about the mean.
    pub fn add_spread(&mut self, theta: &[f64]) {
        for (k, &x) in theta.iter().enumerate() {
            let d = x - self.gmean[k];
            self.gr2 += d * d;
        }
    }

    fn mean_eta(&self) -> f64 {
        if self.eta_count == 0 { 0.0 } else { self.sum_eta / self.eta_count as f64 }
    }

    fn min_eta_or_zero(&self) -> f64 {
        if self.eta_count == 0 { 0.0 } else { self.min_eta }
    }
}

/// Serialized [`StopTracker`] state — what travels in the cluster's
/// leader-election handoff message (plain data; the simulated network
/// clones it like any payload).
#[derive(Debug, Clone, PartialEq)]
pub struct StopSnapshot {
    pub checker: CheckerState,
    pub stats: Vec<IterStats>,
    pub gmean_prev: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// The stop state machine (see module docs). One per recording surface:
/// the engine, the sharded leader, the async fold cursor, the cluster's
/// designated machine.
pub struct StopTracker {
    max_iters: usize,
    eta0: f64,
    checker: ConvergenceChecker,
    pub recorder: Recorder,
    /// previous round's global mean (starts at zero, like the engines)
    gmean_prev: Vec<f64>,
    /// Chan-fold scratch for the partials flavour
    fold: RunningFold,
    pub iterations: usize,
    pub converged: bool,
}

impl StopTracker {
    pub fn new(dim: usize, tol: f64, patience: usize, warmup: usize,
               max_iters: usize, eta0: f64) -> StopTracker {
        StopTracker {
            max_iters,
            eta0,
            checker: ConvergenceChecker::new(tol)
                .with_patience(patience)
                .with_warmup(warmup),
            recorder: Recorder::with_capacity(max_iters),
            gmean_prev: vec![0.0; dim],
            fold: RunningFold::new(dim),
            iterations: 0,
            converged: false,
        }
    }

    /// Fresh checker/recorder for a new run. Fold memory (`gmean_prev`)
    /// deliberately persists: a caller driving raw steps across runs keeps
    /// the legacy engine's continuation semantics.
    pub fn reset_run(&mut self) {
        self.checker.reset();
        self.recorder = Recorder::with_capacity(self.max_iters);
        self.iterations = 0;
        self.converged = false;
    }

    /// Derive the verdict from a mean + spread pair — shared tail of both
    /// flavours: `gs2 = ‖ḡ − ḡ_prev‖²`, dual `= η⁰ √n √gs2`, then roll
    /// the mean memory forward.
    fn verdict(&mut self, gmean: &[f64], gr2: f64, n: usize) -> (f64, f64) {
        let mut gs2 = 0.0;
        for (k, &g) in gmean.iter().enumerate() {
            let d = g - self.gmean_prev[k];
            gs2 += d * d;
        }
        let global_primal = gr2.sqrt();
        let global_dual = self.eta0 * (n as f64).sqrt() * gs2.sqrt();
        self.gmean_prev.copy_from_slice(gmean);
        (global_primal, global_dual)
    }

    /// Fold a completed flat round (engine/async flavour) into the round
    /// verdict. The caller has already run `begin → add_node/add_theta →
    /// finish_mean → add_spread`.
    pub fn round_flat(&mut self, flat: &FlatRound) -> GlobalRound {
        let (global_primal, global_dual) =
            self.verdict(&flat.gmean, flat.gr2, flat.count);
        GlobalRound {
            objective: flat.objective,
            global_primal,
            global_dual,
            max_primal: flat.max_primal,
            max_dual: flat.max_dual,
            mean_eta: flat.mean_eta(),
            min_eta: flat.min_eta_or_zero(),
            max_eta: flat.max_eta,
            folded_nodes: flat.count,
        }
    }

    /// Fold per-shard centered partials (coordinator/cluster flavour) in
    /// the order the iterator yields them — callers fold in shard /
    /// machine-id (= node-id) order for reproducibility. The Chan
    /// combination itself lives in [`RunningFold`].
    pub fn round_partials<'a, I>(&mut self, parts: I) -> GlobalRound
    where
        I: IntoIterator<Item = &'a StatPartial>,
    {
        self.fold.reset();
        for p in parts {
            self.fold.absorb(p);
        }
        let gr2 = self.fold.gr2.max(0.0);
        let n = self.fold.agg_n;
        // the borrow checker will not let `verdict` take &self.fold.gmean;
        // swap it out for the call (no allocation, no copy)
        let gmean = std::mem::take(&mut self.fold.gmean);
        let (global_primal, global_dual) = self.verdict(&gmean, gr2, n);
        self.fold.gmean = gmean;
        GlobalRound {
            objective: self.fold.objective,
            global_primal,
            global_dual,
            max_primal: self.fold.max_primal,
            max_dual: self.fold.max_dual,
            mean_eta: self.fold.mean_eta(),
            min_eta: self.fold.min_eta(),
            max_eta: self.fold.eta_max,
            folded_nodes: n,
        }
    }

    /// Commit a recorded round: push the stats, advance the iteration
    /// count, run the convergence check. Returns `true` when the run
    /// should stop (converged, or the round budget is spent).
    pub fn commit(&mut self, t: usize, stats: IterStats) -> bool {
        let objective = stats.objective;
        self.recorder.push(stats);
        self.iterations = t + 1;
        let hit = self.checker.update(objective);
        if hit {
            self.converged = true;
        }
        hit || t + 1 >= self.max_iters
    }

    /// Move the recorded curves out (end of run).
    pub fn take_recorder(&mut self) -> Recorder {
        std::mem::take(&mut self.recorder)
    }

    /// Serialize the full tracker state (cluster leader handoff).
    pub fn snapshot(&self) -> StopSnapshot {
        StopSnapshot {
            checker: self.checker.snapshot(),
            stats: self.recorder.stats.clone(),
            gmean_prev: self.gmean_prev.clone(),
            iterations: self.iterations,
            converged: self.converged,
        }
    }

    /// Resume from a serialized tracker state (the receiving leader).
    pub fn resume(&mut self, snap: StopSnapshot) {
        self.checker.restore(&snap.checker);
        self.recorder = Recorder { stats: snap.stats };
        self.gmean_prev = snap.gmean_prev;
        self.iterations = snap.iterations;
        self.converged = snap.converged;
    }
}
