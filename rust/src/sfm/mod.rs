//! Distributed affine structure-from-motion on top of D-PPCA.
//!
//! Formulation (following Yoon & Pavlovic, NIPS'12, as used in the paper's
//! §5.2): the 2F×N tracked-feature matrix is centred per frame (removing
//! the affine translation) and **transposed**, giving an N×2F data matrix
//! whose columns (one per frame coordinate row) are the PPCA samples and
//! whose D = N rows are the tracked points. With latent dimension M = 3
//! the PPCA projection matrix W ∈ R^{N×3} *is* the reconstructed 3-D
//! structure, so running consensus D-PPCA over cameras — each owning its
//! own frames (= its own sample columns) — jointly estimates the shared
//! structure while camera motion lands in the per-sample latents E[z].
//!
//! Error metric: maximum principal angle between a node's W and the
//! centralized SVD structure basis (the paper's ground truth).

use crate::error::Result;
use crate::linalg::{max_principal_angle_deg, Mat, Svd};

/// Centre each row of a 2F×N measurement matrix (per-frame centroid
/// subtraction — removes the affine translation component).
pub fn center_rows(measurements: &Mat) -> Mat {
    let mut m = measurements.clone();
    let n = m.cols() as f64;
    for r in 0..m.rows() {
        let mean: f64 = m.row(r).iter().sum::<f64>() / n;
        for c in 0..m.cols() {
            m[(r, c)] -= mean;
        }
    }
    m
}

/// Build the D-PPCA input: centred, transposed measurement matrix
/// (N points × 2F frame-rows). Samples = columns.
pub fn ppca_input(measurements: &Mat) -> Mat {
    center_rows(measurements).t()
}

/// Centralized SVD baseline: the rank-3 structure basis (N×3) of the
/// centred measurement matrix — the paper's ground truth for the subspace
/// angle. Also returns the rank-3 reconstruction error (relative
/// Frobenius) as a data-quality diagnostic.
pub fn svd_structure(measurements: &Mat) -> Result<(Mat, f64)> {
    let centred = center_rows(measurements);
    let svd = Svd::new(&centred)?;
    // centred is 2F×N: structure basis = top-3 right singular vectors
    let basis = svd.v.col_slice(0, 3);
    let recon = svd.low_rank(3);
    let err = (&recon - &centred).fro_norm() / centred.fro_norm().max(1e-300);
    Ok((basis, err))
}

/// Subspace-angle error (degrees) of an estimated structure `w` (N×3)
/// against the SVD baseline.
pub fn structure_error_deg(w: &Mat, baseline: &Mat) -> Result<f64> {
    max_principal_angle_deg(w, baseline)
}

/// Split frames evenly over cameras: camera i receives the *sample
/// columns* of the transposed matrix that belong to its frames. Returns
/// per-camera (N × 2F_i) data blocks.
pub fn split_frames(ppca_data: &Mat, frames: usize, cameras: usize) -> Vec<Mat> {
    assert_eq!(ppca_data.cols(), 2 * frames, "ppca data must be N×2F");
    let part = crate::data::even_split(frames, cameras);
    part.ranges
        .iter()
        .map(|&(lo, hi)| ppca_data.col_slice(2 * lo, 2 * hi))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::turntable::TurntableSpec;

    fn obj() -> crate::data::TurntableObject {
        TurntableSpec::default().generate("Standing", 42)
    }

    #[test]
    fn centering_zeroes_row_means() {
        let m = center_rows(&obj().measurements);
        for r in 0..m.rows() {
            let mean: f64 = m.row(r).iter().sum::<f64>() / m.cols() as f64;
            assert!(mean.abs() < 1e-12);
        }
    }

    #[test]
    fn svd_baseline_matches_true_structure() {
        let o = obj();
        let (basis, err) = svd_structure(&o.measurements).unwrap();
        assert_eq!(basis.shape(), (120, 3));
        assert!(err < 0.02, "rank-3 residual {err}");
        // per-frame centring removes the centroid, so the SVD basis spans
        // the *centred* structure — centre before comparing
        let mut s = o.structure.clone();
        for k in 0..3 {
            let mean: f64 = s.col(k).iter().sum::<f64>() / s.rows() as f64;
            for r in 0..s.rows() {
                s[(r, k)] -= mean;
            }
        }
        let angle = structure_error_deg(&s, &basis).unwrap();
        assert!(angle < 2.0, "angle {angle}");
    }

    #[test]
    fn split_covers_all_frames() {
        let o = obj();
        let data = ppca_input(&o.measurements);
        let blocks = split_frames(&data, o.frames, 5);
        assert_eq!(blocks.len(), 5);
        let total: usize = blocks.iter().map(|b| b.cols()).sum();
        assert_eq!(total, 2 * o.frames);
        for b in &blocks {
            assert_eq!(b.rows(), 120);
            assert_eq!(b.cols(), 12); // 30 frames / 5 cameras × 2 rows
        }
    }

    #[test]
    fn perfect_rank3_data_has_zero_svd_error() {
        // noiseless object: rank-3 reconstruction must be exact
        let spec = TurntableSpec { noise: 0.0, ..Default::default() };
        let o = spec.generate("BoxStuff", 7);
        let (_, err) = svd_structure(&o.measurements).unwrap();
        assert!(err < 1e-10, "err {err}");
    }
}
