//! Hand-rolled JSON codec for the machine-level protocol messages — the
//! process transport's wire format, in the `net/plan.rs` discipline
//! (explicit field validation, [`Error::Config`] with context on every
//! mismatch; serde is unavailable offline).
//!
//! Every [`Payload`] variant (and the [`StopSnapshot`] the `Checker`
//! handoff carries) round-trips *exactly*: finite f64 fields ride as
//! JSON numbers (the emitter's shortest-round-trip formatting is
//! value-exact), while the four values JSON numbers cannot carry —
//! `inf`, `-inf`, `nan`, `-0` (the emitter's integer fast path drops
//! the sign of negative zero) — ride as those literal strings. The
//! fresh-state sentinels make this load-bearing, not cosmetic: a new
//! checker starts at `f_min = +inf, f_max = -inf`, and a machine's
//! `latest_globals` starts at `(inf, inf)`.

use crate::error::{Error, Result};
use crate::graph::NodeId;
use crate::kernel::StopSnapshot;
use crate::metrics::{CheckerState, IterStats, StatPartial};
use crate::obs::TraceCtx;
use crate::util::json::{arr, num, obj, s, Json};

use super::sim::Payload;

// -- f64 with non-finite sentinels ------------------------------------------

pub(crate) fn fnum(x: f64) -> Json {
    if x.is_nan() {
        s("nan")
    } else if x == f64::INFINITY {
        s("inf")
    } else if x == f64::NEG_INFINITY {
        s("-inf")
    } else if x == 0.0 && x.is_sign_negative() {
        s("-0")
    } else {
        num(x)
    }
}

pub(crate) fn f64_of(v: &Json, what: &str) -> Result<f64> {
    match v {
        Json::Num(x) => Ok(*x),
        Json::Str(t) => match t.as_str() {
            "nan" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "-0" => Ok(-0.0),
            _ => Err(Error::Config(format!("codec: {what}: bad f64 sentinel '{t}'"))),
        },
        _ => Err(Error::Config(format!("codec: {what}: expected number"))),
    }
}

fn req_f64(v: &Json, key: &str, what: &str) -> Result<f64> {
    let field = v
        .get(key)
        .ok_or_else(|| Error::Config(format!("codec: {what}: missing '{key}'")))?;
    f64_of(field, key)
}

fn req_u64(v: &Json, key: &str, what: &str) -> Result<u64> {
    let x = v
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Config(format!("codec: {what}: missing count '{key}'")))?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(Error::Config(format!("codec: {what}: '{key}' not a count")));
    }
    Ok(x as u64)
}

fn req_usize(v: &Json, key: &str, what: &str) -> Result<usize> {
    Ok(req_u64(v, key, what)? as usize)
}

fn req_bool(v: &Json, key: &str, what: &str) -> Result<bool> {
    v.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| Error::Config(format!("codec: {what}: missing bool '{key}'")))
}

fn req_arr<'a>(v: &'a Json, key: &str, what: &str) -> Result<&'a [Json]> {
    v.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Config(format!("codec: {what}: missing array '{key}'")))
}

fn f64s(xs: &[f64]) -> Json {
    arr(xs.iter().map(|&x| fnum(x)).collect())
}

fn f64s_of(v: &Json, key: &str, what: &str) -> Result<Vec<f64>> {
    req_arr(v, key, what)?.iter().map(|x| f64_of(x, key)).collect()
}

// -- trace context ------------------------------------------------------------

/// Encode a frame's [`TraceCtx`] for the process wire — the `"ctx"` key
/// on the *routed line* (next to `"src"`/`"dst"`/`"body"`), not inside
/// the payload body, so payload round-trips stay byte-identical to the
/// pre-tracing wire.
pub(crate) fn ctx_to_json(ctx: TraceCtx) -> Json {
    obj(vec![
        ("m", num(ctx.machine as f64)),
        ("r", num(ctx.round as f64)),
        ("s", num(ctx.seq as f64)),
    ])
}

/// Decode an optional wire trace context. Absent → [`TraceCtx::default`]
/// — the same interop trick as `ProcInit.obs`: a peer built before this
/// field simply produces frames with the zero context.
pub(crate) fn ctx_from_json(v: Option<&Json>) -> Result<TraceCtx> {
    match v {
        None => Ok(TraceCtx::default()),
        Some(c) => Ok(TraceCtx {
            round: req_u64(c, "r", "ctx")?,
            machine: req_usize(c, "m", "ctx")?,
            seq: req_u64(c, "s", "ctx")?,
        }),
    }
}

// -- component structs -------------------------------------------------------

fn stat_partial_to_json(p: &StatPartial) -> Json {
    obj(vec![
        ("f_sum", fnum(p.f_sum)),
        ("max_primal", fnum(p.max_primal)),
        ("max_dual", fnum(p.max_dual)),
        ("eta_min", fnum(p.eta_min)),
        ("eta_max", fnum(p.eta_max)),
        ("eta_sum", fnum(p.eta_sum)),
        ("eta_count", num(p.eta_count as f64)),
        ("theta_sum", f64s(&p.theta_sum)),
        ("node_count", num(p.node_count as f64)),
        ("centered_sq", fnum(p.centered_sq)),
    ])
}

fn stat_partial_from_json(v: &Json) -> Result<StatPartial> {
    const W: &str = "partial";
    Ok(StatPartial {
        f_sum: req_f64(v, "f_sum", W)?,
        max_primal: req_f64(v, "max_primal", W)?,
        max_dual: req_f64(v, "max_dual", W)?,
        eta_min: req_f64(v, "eta_min", W)?,
        eta_max: req_f64(v, "eta_max", W)?,
        eta_sum: req_f64(v, "eta_sum", W)?,
        eta_count: req_usize(v, "eta_count", W)?,
        theta_sum: f64s_of(v, "theta_sum", W)?,
        node_count: req_usize(v, "node_count", W)?,
        centered_sq: req_f64(v, "centered_sq", W)?,
    })
}

fn iter_stats_to_json(st: &IterStats) -> Json {
    obj(vec![
        ("iter", num(st.iter as f64)),
        ("objective", fnum(st.objective)),
        ("max_primal", fnum(st.max_primal)),
        ("max_dual", fnum(st.max_dual)),
        ("mean_eta", fnum(st.mean_eta)),
        ("min_eta", fnum(st.min_eta)),
        ("max_eta", fnum(st.max_eta)),
        ("app_error", fnum(st.app_error)),
    ])
}

fn iter_stats_from_json(v: &Json) -> Result<IterStats> {
    const W: &str = "iter_stats";
    Ok(IterStats {
        iter: req_usize(v, "iter", W)?,
        objective: req_f64(v, "objective", W)?,
        max_primal: req_f64(v, "max_primal", W)?,
        max_dual: req_f64(v, "max_dual", W)?,
        mean_eta: req_f64(v, "mean_eta", W)?,
        min_eta: req_f64(v, "min_eta", W)?,
        max_eta: req_f64(v, "max_eta", W)?,
        app_error: req_f64(v, "app_error", W)?,
    })
}

fn checker_to_json(c: &CheckerState) -> Json {
    obj(vec![
        ("prev", match c.prev {
            None => Json::Null,
            Some(x) => fnum(x),
        }),
        ("f_min", fnum(c.f_min)),
        ("f_max", fnum(c.f_max)),
        ("streak", num(c.streak as f64)),
        ("seen", num(c.seen as f64)),
    ])
}

fn checker_from_json(v: &Json) -> Result<CheckerState> {
    const W: &str = "checker";
    let prev = match v
        .get("prev")
        .ok_or_else(|| Error::Config(format!("codec: {W}: missing 'prev'")))?
    {
        Json::Null => None,
        other => Some(f64_of(other, "prev")?),
    };
    Ok(CheckerState {
        prev,
        f_min: req_f64(v, "f_min", W)?,
        f_max: req_f64(v, "f_max", W)?,
        streak: req_usize(v, "streak", W)?,
        seen: req_usize(v, "seen", W)?,
    })
}

/// Encode a [`StopSnapshot`] (the leader-election handoff state).
pub fn snapshot_to_json(snap: &StopSnapshot) -> Json {
    obj(vec![
        ("checker", checker_to_json(&snap.checker)),
        ("stats", arr(snap.stats.iter().map(iter_stats_to_json).collect())),
        ("gmean_prev", f64s(&snap.gmean_prev)),
        ("iterations", num(snap.iterations as f64)),
        ("converged", Json::Bool(snap.converged)),
    ])
}

/// Decode a [`StopSnapshot`].
pub fn snapshot_from_json(v: &Json) -> Result<StopSnapshot> {
    const W: &str = "snapshot";
    Ok(StopSnapshot {
        checker: checker_from_json(v.req("checker")?)?,
        stats: req_arr(v, "stats", W)?
            .iter()
            .map(iter_stats_from_json)
            .collect::<Result<Vec<_>>>()?,
        gmean_prev: f64s_of(v, "gmean_prev", W)?,
        iterations: req_usize(v, "iterations", W)?,
        converged: req_bool(v, "converged", W)?,
    })
}

// -- payload -----------------------------------------------------------------

fn node_vec_to_json(nodes: &[(NodeId, Vec<f64>)]) -> Json {
    arr(nodes
        .iter()
        .map(|(id, th)| arr(vec![num(*id as f64), f64s(th)]))
        .collect())
}

fn node_vec_from_json(v: &Json, key: &str, what: &str)
                      -> Result<Vec<(NodeId, Vec<f64>)>> {
    req_arr(v, key, what)?
        .iter()
        .map(|pair| {
            let items = pair.as_arr().ok_or_else(|| {
                Error::Config(format!("codec: {what}: '{key}' entry not a pair"))
            })?;
            if items.len() != 2 {
                return Err(Error::Config(format!(
                    "codec: {what}: '{key}' entry not a pair"
                )));
            }
            let id = items[0].as_usize().ok_or_else(|| {
                Error::Config(format!("codec: {what}: bad node id in '{key}'"))
            })?;
            let th: Vec<f64> = items[1]
                .as_arr()
                .ok_or_else(|| {
                    Error::Config(format!("codec: {what}: bad θ in '{key}'"))
                })?
                .iter()
                .map(|x| f64_of(x, key))
                .collect::<Result<Vec<_>>>()?;
            Ok((id, th))
        })
        .collect()
}

/// Encode a machine-level protocol message as a JSON value (one line of
/// the process transport's wire format once `to_string()`-ed).
pub fn payload_to_json(p: &Payload) -> Json {
    match p {
        Payload::Theta { stamp, theta } => obj(vec![
            ("kind", s("theta")),
            ("stamp", num(*stamp as f64)),
            ("theta", f64s(theta)),
        ]),
        Payload::Eta { stamp, eta } => obj(vec![
            ("kind", s("eta")),
            ("stamp", num(*stamp as f64)),
            ("eta", fnum(*eta)),
        ]),
        Payload::BoundaryTheta { stamp, nodes } => obj(vec![
            ("kind", s("btheta")),
            ("stamp", num(*stamp as f64)),
            ("nodes", node_vec_to_json(nodes)),
        ]),
        Payload::BoundaryEta { stamp, edges } => obj(vec![
            ("kind", s("beta")),
            ("stamp", num(*stamp as f64)),
            ("edges", arr(edges
                .iter()
                .map(|(i, j, e)| {
                    arr(vec![num(*i as f64), num(*j as f64), fnum(*e)])
                })
                .collect())),
        ]),
        Payload::Part { round, entries, thetas } => obj(vec![
            ("kind", s("part")),
            ("round", num(*round as f64)),
            ("entries", arr(entries
                .iter()
                .map(|(mid, parts)| {
                    arr(vec![
                        num(*mid as f64),
                        arr(parts.iter().map(stat_partial_to_json).collect()),
                    ])
                })
                .collect())),
            ("thetas", node_vec_to_json(thetas)),
        ]),
        Payload::Verdict { round, global_primal, global_dual } => obj(vec![
            ("kind", s("verdict")),
            ("round", num(*round as f64)),
            ("gp", fnum(*global_primal)),
            ("gd", fnum(*global_dual)),
        ]),
        Payload::Gossip { round, mass, weight, maxes } => obj(vec![
            ("kind", s("gossip")),
            ("round", num(*round as f64)),
            ("mass", f64s(mass)),
            ("weight", fnum(*weight)),
            ("maxes", f64s(&maxes[..])),
        ]),
        Payload::Checker { cursor, snap } => obj(vec![
            ("kind", s("checker")),
            ("cursor", num(*cursor as f64)),
            ("snap", snapshot_to_json(snap)),
        ]),
        Payload::Stop { round, converged } => obj(vec![
            ("kind", s("stop")),
            ("round", num(*round as f64)),
            ("converged", Json::Bool(*converged)),
        ]),
    }
}

/// Decode a machine-level protocol message.
pub fn payload_from_json(v: &Json) -> Result<Payload> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Config("codec: payload: missing 'kind'".into()))?;
    match kind {
        "theta" => Ok(Payload::Theta {
            stamp: req_u64(v, "stamp", "theta")?,
            theta: f64s_of(v, "theta", "theta")?,
        }),
        "eta" => Ok(Payload::Eta {
            stamp: req_u64(v, "stamp", "eta")?,
            eta: req_f64(v, "eta", "eta")?,
        }),
        "btheta" => Ok(Payload::BoundaryTheta {
            stamp: req_u64(v, "stamp", "btheta")?,
            nodes: node_vec_from_json(v, "nodes", "btheta")?,
        }),
        "beta" => {
            let edges = req_arr(v, "edges", "beta")?
                .iter()
                .map(|t| {
                    let items = t.as_arr().filter(|a| a.len() == 3).ok_or_else(|| {
                        Error::Config("codec: beta: edge not a triple".into())
                    })?;
                    let i = items[0].as_usize().ok_or_else(|| {
                        Error::Config("codec: beta: bad node id".into())
                    })?;
                    let j = items[1].as_usize().ok_or_else(|| {
                        Error::Config("codec: beta: bad node id".into())
                    })?;
                    Ok((i, j, f64_of(&items[2], "edges")?))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(Payload::BoundaryEta { stamp: req_u64(v, "stamp", "beta")?, edges })
        }
        "part" => {
            let entries = req_arr(v, "entries", "part")?
                .iter()
                .map(|pair| {
                    let items = pair.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                        Error::Config("codec: part: entry not a pair".into())
                    })?;
                    let mid = items[0].as_usize().ok_or_else(|| {
                        Error::Config("codec: part: bad machine id".into())
                    })?;
                    let parts = items[1]
                        .as_arr()
                        .ok_or_else(|| {
                            Error::Config("codec: part: partial list missing".into())
                        })?
                        .iter()
                        .map(stat_partial_from_json)
                        .collect::<Result<Vec<_>>>()?;
                    Ok((mid, parts))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(Payload::Part {
                round: req_u64(v, "round", "part")?,
                entries,
                thetas: node_vec_from_json(v, "thetas", "part")?,
            })
        }
        "verdict" => Ok(Payload::Verdict {
            round: req_u64(v, "round", "verdict")?,
            global_primal: req_f64(v, "gp", "verdict")?,
            global_dual: req_f64(v, "gd", "verdict")?,
        }),
        "gossip" => {
            let maxes_v = f64s_of(v, "maxes", "gossip")?;
            let maxes: [f64; 4] = maxes_v.try_into().map_err(|_| {
                Error::Config("codec: gossip: 'maxes' must have 4 entries".into())
            })?;
            Ok(Payload::Gossip {
                round: req_u64(v, "round", "gossip")?,
                mass: f64s_of(v, "mass", "gossip")?,
                weight: req_f64(v, "weight", "gossip")?,
                maxes,
            })
        }
        "checker" => Ok(Payload::Checker {
            cursor: req_u64(v, "cursor", "checker")?,
            snap: Box::new(snapshot_from_json(v.req("snap")?)?),
        }),
        "stop" => Ok(Payload::Stop {
            round: req_u64(v, "round", "stop")?,
            converged: req_bool(v, "converged", "stop")?,
        }),
        other => Err(Error::Config(format!("codec: unknown payload kind '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Recorder;

    /// Tricky f64s: signed zeros, subnormals, shortest-round-trip
    /// stressors, huge/tiny magnitudes, and the three non-finites.
    const HARD: [f64; 12] = [
        0.0, -0.0, 1.5, 0.1, 1e-17, 1e300, -2.5e-300, 5e-324,
        f64::MAX, f64::MIN_POSITIVE, f64::INFINITY, f64::NEG_INFINITY,
    ];

    fn partial(k: usize) -> StatPartial {
        StatPartial {
            f_sum: HARD[k % HARD.len()],
            max_primal: 0.25,
            max_dual: HARD[(k + 1) % HARD.len()],
            eta_min: f64::INFINITY,
            eta_max: f64::NEG_INFINITY,
            eta_sum: 7.75,
            eta_count: k,
            theta_sum: vec![1.0, HARD[(k + 2) % HARD.len()]],
            node_count: 3 + k,
            centered_sq: 1e-30,
        }
    }

    fn snap() -> StopSnapshot {
        StopSnapshot {
            checker: CheckerState {
                prev: Some(-0.0),
                f_min: f64::INFINITY,
                f_max: f64::NEG_INFINITY,
                streak: 2,
                seen: 9,
            },
            stats: vec![IterStats {
                iter: 4,
                objective: 12.125,
                max_primal: 1e-9,
                max_dual: 3.0,
                mean_eta: 0.1,
                min_eta: 0.05,
                max_eta: 0.2,
                app_error: f64::NAN,
            }],
            gmean_prev: HARD.to_vec(),
            iterations: 5,
            converged: false,
        }
    }

    fn all_payloads() -> Vec<Payload> {
        vec![
            Payload::Theta { stamp: 3, theta: HARD.to_vec() },
            Payload::Eta { stamp: 0, eta: -0.0 },
            Payload::BoundaryTheta {
                stamp: 7,
                nodes: vec![(0, vec![1.5, -0.0]), (41, HARD.to_vec())],
            },
            Payload::BoundaryEta {
                stamp: 2,
                edges: vec![(1, 2, 0.5), (9, 0, f64::INFINITY)],
            },
            Payload::Part {
                round: 11,
                entries: vec![(0, vec![partial(0), partial(1)]), (2, vec![])],
                thetas: vec![(0, vec![0.5; 4]), (2, vec![-0.0, 1e300])],
            },
            Payload::Verdict {
                round: 6,
                global_primal: f64::INFINITY,
                global_dual: 5e-324,
            },
            Payload::Gossip {
                round: 1,
                mass: vec![4.0, 0.0, 17.25, 0.5, 8.0, 1.0, -3.5],
                weight: 0.0078125,
                maxes: [0.1, 0.2, 0.3, f64::NEG_INFINITY],
            },
            Payload::Checker { cursor: 5, snap: Box::new(snap()) },
            Payload::Stop { round: 250, converged: true },
        ]
    }

    #[test]
    fn every_variant_round_trips_exactly() {
        for p in all_payloads() {
            let line = payload_to_json(&p).to_string();
            let back = payload_from_json(&Json::parse(&line).unwrap()).unwrap();
            // byte-identical re-serialization covers NaN fields, which
            // PartialEq cannot
            assert_eq!(payload_to_json(&back).to_string(), line,
                       "re-encode mismatch for {line}");
        }
    }

    #[test]
    fn nan_free_variants_compare_equal_after_round_trip() {
        for p in all_payloads() {
            if matches!(p, Payload::Checker { .. }) {
                continue; // carries the NaN app_error above
            }
            let line = payload_to_json(&p).to_string();
            let back = payload_from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(back, p, "value mismatch for {line}");
        }
    }

    #[test]
    fn signed_zero_and_nonfinites_survive_bit_level() {
        let p = Payload::Theta {
            stamp: 1,
            theta: vec![-0.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY],
        };
        let line = payload_to_json(&p).to_string();
        let Payload::Theta { theta, .. } =
            payload_from_json(&Json::parse(&line).unwrap()).unwrap()
        else {
            panic!("kind changed");
        };
        assert!(theta[0] == 0.0 && theta[0].is_sign_negative());
        assert!(theta[1].is_nan());
        assert_eq!(theta[2], f64::INFINITY);
        assert_eq!(theta[3], f64::NEG_INFINITY);
    }

    #[test]
    fn snapshot_resumes_a_tracker_identically() {
        // the handoff contract end-to-end: snapshot → JSON → resume
        use crate::kernel::StopTracker;
        let snap = snap();
        let encoded = snapshot_to_json(&snap).to_string();
        let back = snapshot_from_json(&Json::parse(&encoded).unwrap()).unwrap();
        let mut a = StopTracker::new(2, 1e-3, 3, 5, 100, 1.0);
        let mut b = StopTracker::new(2, 1e-3, 3, 5, 100, 1.0);
        a.resume(snap);
        b.resume(back);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.converged, b.converged);
        let ra: &Recorder = &a.recorder;
        let rb: &Recorder = &b.recorder;
        assert_eq!(ra.stats.len(), rb.stats.len());
        // IterStats contains a NaN app_error: compare through re-encode
        assert_eq!(
            arr(ra.stats.iter().map(iter_stats_to_json).collect()).to_string(),
            arr(rb.stats.iter().map(iter_stats_to_json).collect()).to_string(),
        );
    }

    #[test]
    fn trace_ctx_round_trips_and_defaults_when_absent() {
        let ctx = TraceCtx { round: 41, machine: 3, seq: 1027 };
        let line = ctx_to_json(ctx).to_string();
        let back = ctx_from_json(Some(&Json::parse(&line).unwrap())).unwrap();
        assert_eq!(back, ctx);
        // absent on the wire (old peer) → zero context, not an error
        assert_eq!(ctx_from_json(None).unwrap(), TraceCtx::default());
        // present but malformed is still an error
        let bad = Json::parse(r#"{"r":1,"m":2}"#).unwrap();
        assert!(ctx_from_json(Some(&bad)).is_err());
    }

    #[test]
    fn decode_rejects_malformed_documents() {
        for bad in [
            r#"{"stamp":1}"#,
            r#"{"kind":"theta"}"#,
            r#"{"kind":"theta","stamp":1.5,"theta":[]}"#,
            r#"{"kind":"gossip","round":0,"mass":[],"weight":1,"maxes":[1,2,3]}"#,
            r#"{"kind":"eta","stamp":1,"eta":"huge"}"#,
            r#"{"kind":"warp","stamp":1}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(payload_from_json(&v).is_err(), "accepted {bad}");
        }
    }
}
