//! Deterministic, seeded discrete-event network simulator.
//!
//! A virtual clock in integer **ticks**, a binary-heap event queue with a
//! monotone sequence number as the tie-break (so simultaneous events pop
//! in schedule order — total determinism even at zero latency), a seeded
//! [`Pcg`] stream for every stochastic decision (per-message latency
//! jitter, Bernoulli loss, duplication), scripted transient partitions and
//! node join/leave schedules, and a bounded event trace (an
//! [`crate::obs::FlightRecorder`]: oldest-first eviction past capacity,
//! evictions counted in `counters.trace_dropped`). Two runs with the
//! same seed and plan produce bit-identical traces — the full log under
//! the capacity, the newest suffix plus an identical drop count above
//! it; the determinism tests in `net::tests` assert exactly that.
//!
//! The simulator is pure transport + clock: it knows which messages exist
//! and when they arrive, but nothing about ADMM. The consumer
//! ([`super::AsyncRunner`]) pops [`Event`]s one at a time and reacts;
//! liveness of the *destination* is the consumer's concern (a message to a
//! node that died in flight is counted/traced here when the consumer
//! reports it via [`NetSim::note_dead_delivery`]).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::NodeId;
use crate::kernel::StopSnapshot;
use crate::metrics::{NetCounters, StatPartial};
use crate::obs::{FlightRecorder, TraceCtx};
use crate::util::rng::Pcg;

/// Virtual time in ticks (dimensionless; latency/timeout parameters give
/// it meaning per scenario).
pub type Ticks = u64;

/// Per-link delivery model applied to every steady-state message.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// fixed propagation delay
    pub base: Ticks,
    /// uniform extra delay in `[0, jitter]` (0 ⇒ deterministic latency)
    pub jitter: Ticks,
    /// Bernoulli message-loss probability
    pub loss: f64,
    /// Bernoulli duplication probability (the copy takes an independent
    /// latency draw, so duplicates can arrive out of order)
    pub dup: f64,
}

impl LinkModel {
    /// The zero-fault oracle link: instantaneous, lossless, no dups.
    pub fn ideal() -> LinkModel {
        LinkModel { base: 0, jitter: 0, loss: 0.0, dup: 0.0 }
    }
}

/// A scripted transient partition: while `start <= now < end`, messages
/// between `group` and its complement are dropped. Node membership is
/// evaluated at send time.
#[derive(Debug, Clone)]
pub struct Partition {
    pub start: Ticks,
    pub end: Ticks,
    pub group: Vec<NodeId>,
}

impl Partition {
    fn cuts(&self, now: Ticks, a: NodeId, b: NodeId) -> bool {
        if now < self.start || now >= self.end {
            return false;
        }
        let ga = self.group.contains(&a);
        let gb = self.group.contains(&b);
        ga != gb
    }
}

/// One scripted churn event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    /// Node activates at `at` (it must be listed in
    /// [`FaultPlan::initially_dormant`] or have left earlier).
    Join { at: Ticks, node: NodeId },
    /// Node halts at `at`; its edges are masked and in-flight messages to
    /// it are dropped on delivery.
    Leave { at: Ticks, node: NodeId },
}

impl ChurnEvent {
    pub fn at(&self) -> Ticks {
        match *self {
            ChurnEvent::Join { at, .. } | ChurnEvent::Leave { at, .. } => at,
        }
    }
}

/// Everything that can go wrong, scripted per scenario.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub link: LinkModel,
    pub partitions: Vec<Partition>,
    pub churn: Vec<ChurnEvent>,
    /// nodes that exist in the frozen graph but only activate at their
    /// scripted `Join`
    pub initially_dormant: Vec<NodeId>,
}

impl FaultPlan {
    /// The zero-fault plan (the oracle scenario).
    pub fn none() -> FaultPlan {
        FaultPlan {
            link: LinkModel::ideal(),
            partitions: Vec::new(),
            churn: Vec::new(),
            initially_dormant: Vec::new(),
        }
    }

    /// Whether a message from `a` to `b` sent at `now` crosses an active
    /// partition cut.
    pub fn partitioned(&self, now: Ticks, a: NodeId, b: NodeId) -> bool {
        self.partitions.iter().any(|p| p.cuts(now, a, b))
    }
}

/// Message payloads. `Theta`/`Eta` belong to the per-node async protocol
/// (see [`super::async_runner`]); the remaining variants belong to the
/// machine-level cluster protocol ([`crate::cluster`]), whose endpoints
/// are *machine* ids. `stamp = r` always means "state of epoch/round r":
/// θ^r, the sender's out-edge penalty η^r, or round-r collective traffic.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    Theta { stamp: u64, theta: Vec<f64> },
    Eta { stamp: u64, eta: f64 },
    /// Cluster boundary batch: θ^{stamp} of every listed (global node id,
    /// parameter) pair the destination machine borders.
    BoundaryTheta { stamp: u64, nodes: Vec<(NodeId, Vec<f64>)> },
    /// Cluster boundary penalties: η^{stamp}_{i→j} per cross edge (i on
    /// the sending machine, j on the receiving one).
    BoundaryEta { stamp: u64, edges: Vec<(NodeId, NodeId, f64)> },
    /// Tree collective, rootward: per-machine statistic partials for one
    /// round, concatenated along the tree (machine id, that machine's
    /// shard partials in shard order). When the run carries an
    /// application metric, `thetas` additionally ships each machine's
    /// flat committed θ^{round+1} span so the root can assemble the
    /// global parameter without reading remote state.
    Part {
        round: u64,
        entries: Vec<(NodeId, Vec<StatPartial>)>,
        thetas: Vec<(NodeId, Vec<f64>)>,
    },
    /// Tree collective, leafward: the folded round verdict.
    Verdict { round: u64, global_primal: f64, global_dual: f64 },
    /// Gossip collective: cumulative push-sum mass for one round (robust
    /// to loss — the receiver consumes deltas of the cumulative stream)
    /// plus the max-gossip statistics `[max_primal, max_dual, max_eta,
    /// −min_eta]`.
    Gossip { round: u64, mass: Vec<f64>, weight: f64, maxes: [f64; 4] },
    /// Cluster leader-election handoff: the departing (or demoted) root
    /// serializes its [`StopSnapshot`] — checker, recorder, verdict
    /// memory — and ships it to the machine resuming the recorder duty;
    /// `cursor` is the next round the receiver will fold.
    Checker { cursor: u64, snap: Box<StopSnapshot> },
    /// Real-transport stop flood: the checker holder announces that the
    /// run ended after folding `round` (converged or out of budget) so
    /// every peer process can exit. Never sent on the simulated
    /// transport, where the driver sees the stop directly.
    Stop { round: u64, converged: bool },
}

impl Payload {
    pub fn stamp(&self) -> u64 {
        match *self {
            Payload::Theta { stamp, .. }
            | Payload::Eta { stamp, .. }
            | Payload::BoundaryTheta { stamp, .. }
            | Payload::BoundaryEta { stamp, .. } => stamp,
            Payload::Part { round, .. }
            | Payload::Verdict { round, .. }
            | Payload::Gossip { round, .. }
            | Payload::Stop { round, .. } => round,
            Payload::Checker { cursor, .. } => cursor,
        }
    }

    pub(crate) fn kind_name(&self) -> &'static str {
        match self {
            Payload::Theta { .. } => "theta",
            Payload::Eta { .. } => "eta",
            Payload::BoundaryTheta { .. } => "btheta",
            Payload::BoundaryEta { .. } => "beta",
            Payload::Part { .. } => "part",
            Payload::Verdict { .. } => "verdict",
            Payload::Gossip { .. } => "gossip",
            Payload::Checker { .. } => "checker",
            Payload::Stop { .. } => "stop",
        }
    }
}

/// Which consumer-armed timer fired (cluster runtime; the async runner
/// uses the dedicated [`Event::Wake`] for its single silence timer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimerKind {
    /// next push-sum exchange tick of an in-flight gossip round
    Gossip,
    /// collective patience expired: retransmit / proceed without stragglers
    Collective,
}

/// What the consumer sees when it pops the queue.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A message arrival. `dup` marks duplicated copies (for the trace);
    /// `ctx` is the sender-minted trace context (both copies of a
    /// duplicated frame share the original's, so the timeline draws two
    /// arrows from one send).
    Deliver { src: NodeId, dst: NodeId, payload: Payload, dup: bool, ctx: TraceCtx },
    /// A silence-timeout wakeup armed by the consumer; `epoch` lets the
    /// consumer discard wakeups that a later advance made stale.
    Wake { node: NodeId, epoch: u64 },
    /// A consumer-armed auxiliary timer (gossip ticks, collective
    /// patience); `epoch` disambiguates stale firings like `Wake`.
    Timer { node: NodeId, kind: TimerKind, epoch: u64 },
    /// Scripted churn firing.
    Join { node: NodeId },
    Leave { node: NodeId },
}

/// Replayable trace entry. Compact on purpose: payload *contents* are
/// omitted (θ vectors would dwarf the trace), but stamps, endpoints and
/// causes are all there, so two traces compare meaningfully.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub at: Ticks,
    pub kind: TraceKind,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceKind {
    Send { src: NodeId, dst: NodeId, what: &'static str, stamp: u64 },
    Deliver { src: NodeId, dst: NodeId, what: &'static str, stamp: u64 },
    DropLoss { src: NodeId, dst: NodeId, stamp: u64 },
    DropPartition { src: NodeId, dst: NodeId, stamp: u64 },
    DropDead { src: NodeId, dst: NodeId, stamp: u64 },
    Duplicate { src: NodeId, dst: NodeId, stamp: u64 },
    Join { node: NodeId },
    Leave { node: NodeId },
    EdgeOff { a: NodeId, b: NodeId },
    EdgeOn { a: NodeId, b: NodeId },
    /// a silent-neighbour fallback read (stamp = what was actually used)
    Fallback { node: NodeId, nbr: NodeId, ideal: u64, used: u64 },
    /// a completed global fold
    Fold { round: u64 },
    /// the run stopped (converged or out of budget) after `rounds` folds
    Stop { rounds: u64 },
    /// a cluster machine gave up waiting on collective traffic for a round
    CollectiveTimeout { machine: NodeId, round: u64 },
    /// a cluster machine substituted a local fold for a missing verdict
    FallbackVerdict { machine: NodeId, round: u64 },
    /// the collective spanning tree was rebuilt with a new root
    Reroot { root: NodeId },
    /// the checker/recorder state was serialized and sent `from → to`
    /// (cluster leader-election handoff)
    Handoff { from: NodeId, to: NodeId },
}

/// Heap entry: ordered by (time, seq) via the derived lexicographic Ord,
/// wrapped in `Reverse` for min-heap behaviour.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Scheduled {
    at: Ticks,
    seq: u64,
    event: Event,
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

// Event contains f64 payloads, so Eq must be asserted manually; payload
// equality is only used by tests comparing deterministic replays, where
// bitwise f64 equality is exactly the intended semantics.
impl Eq for Event {}

/// The simulator (see module docs).
pub struct NetSim {
    now: Ticks,
    seq: u64,
    /// Frames minted so far — the `seq` of the next [`TraceCtx`].
    /// Independent of the scheduler's `seq` tie-break so minting can
    /// never perturb event ordering; advances for dropped frames too
    /// (a drop still *was* a send, and the counter must not depend on
    /// fault outcomes differently than the rng stream already does).
    frames: u64,
    queue: BinaryHeap<Reverse<Scheduled>>,
    rng: Pcg,
    plan: FaultPlan,
    tracing: bool,
    trace: FlightRecorder<TraceEvent>,
    pub counters: NetCounters,
}

impl NetSim {
    pub fn new(seed: u64, plan: FaultPlan, tracing: bool) -> NetSim {
        let cap = if tracing { crate::obs::DEFAULT_TRACE_CAPACITY } else { 0 };
        let mut sim = NetSim {
            now: 0,
            seq: 0,
            frames: 0,
            queue: BinaryHeap::new(),
            // dedicated stream so network randomness never perturbs the
            // optimization seeds
            rng: Pcg::new(seed, 0x5E7),
            plan,
            tracing,
            trace: FlightRecorder::new(cap),
            counters: NetCounters::default(),
        };
        // churn is part of the plan; schedule it up-front so the queue is
        // the single source of "what happens next"
        let churn = sim.plan.churn.clone();
        for ev in churn {
            match ev {
                ChurnEvent::Join { at, node } => sim.schedule(at, Event::Join { node }),
                ChurnEvent::Leave { at, node } => sim.schedule(at, Event::Leave { node }),
            }
        }
        sim
    }

    /// Current virtual time.
    pub fn now(&self) -> Ticks {
        self.now
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Append a consumer-side trace entry (fallback reads, folds, topology
    /// decisions) at the current virtual time. The flight recorder is
    /// bounded: past capacity the oldest entry is evicted and
    /// `counters.trace_dropped` advances.
    pub fn record(&mut self, kind: TraceKind) {
        if self.tracing {
            self.trace.push(TraceEvent { at: self.now, kind });
            self.counters.trace_dropped = self.trace.dropped();
        }
    }

    /// Resize the flight recorder (run setup only — discards anything
    /// already recorded). Capacity 0 with tracing on counts every event
    /// as dropped.
    pub fn set_trace_capacity(&mut self, cap: usize) {
        self.trace = FlightRecorder::new(cap);
    }

    /// Retained trace events so far (≤ the recorder's capacity).
    pub fn trace_len(&self) -> usize {
        self.trace.len()
    }

    /// Take the retained trace in chronological order, leaving the
    /// recorder empty. The eviction count stays in
    /// `counters.trace_dropped`.
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        self.counters.trace_dropped = self.trace.dropped();
        self.trace.drain()
    }

    /// Schedule an event at absolute time `at` (clamped to now).
    pub fn schedule(&mut self, at: Ticks, event: Event) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Reverse(Scheduled { at, seq, event }));
    }

    /// Send a protocol message, applying the fault plan. `reliable`
    /// bypasses loss/duplication/partitions (used for the one-shot join
    /// handshake, so a node that ever had a live neighbour also has a
    /// cache entry for it); latency still applies. Returns the frame's
    /// minted [`TraceCtx`] (also stamped on the eventual `Deliver`
    /// event) — minting is one integer increment on a counter disjoint
    /// from the scheduler tie-break and the rng stream, so it is
    /// identical whether or not anyone records the returned context.
    pub fn send(&mut self, src: NodeId, dst: NodeId, payload: Payload, reliable: bool) -> TraceCtx {
        self.counters.sent += 1;
        let stamp = payload.stamp();
        let what = payload.kind_name();
        let ctx = TraceCtx { round: stamp, machine: src, seq: self.frames };
        self.frames += 1;
        self.record(TraceKind::Send { src, dst, what, stamp });
        if !reliable {
            if self.plan.partitioned(self.now, src, dst) {
                self.counters.dropped_partition += 1;
                self.record(TraceKind::DropPartition { src, dst, stamp });
                return ctx;
            }
            if self.plan.link.loss > 0.0 && self.rng.f64() < self.plan.link.loss {
                self.counters.dropped_loss += 1;
                self.record(TraceKind::DropLoss { src, dst, stamp });
                return ctx;
            }
        }
        let copies = if !reliable && self.plan.link.dup > 0.0
            && self.rng.f64() < self.plan.link.dup
        {
            self.counters.duplicated += 1;
            self.record(TraceKind::Duplicate { src, dst, stamp });
            2
        } else {
            1
        };
        for copy in 0..copies {
            let latency = self.sample_latency();
            self.schedule(self.now + latency, Event::Deliver {
                src,
                dst,
                payload: payload.clone(),
                dup: copy > 0,
                ctx,
            });
        }
        ctx
    }

    fn sample_latency(&mut self) -> Ticks {
        let l = self.plan.link;
        if l.jitter == 0 {
            l.base
        } else {
            l.base + self.rng.below(l.jitter as usize + 1) as Ticks
        }
    }

    /// Pop the next event *without* advancing the virtual clock: the
    /// consumer decides whether the event is meaningful (a stale wakeup
    /// whose epoch no longer matches should not drag virtual time forward)
    /// and calls [`NetSim::advance_to`] before handling it.
    pub fn pop(&mut self) -> Option<(Ticks, Event)> {
        let Reverse(s) = self.queue.pop()?;
        debug_assert!(s.at >= self.now, "virtual clock must be monotone");
        Some((s.at, s.event))
    }

    /// Advance the virtual clock to `at` (monotone).
    pub fn advance_to(&mut self, at: Ticks) {
        debug_assert!(at >= self.now);
        self.now = at;
    }

    /// [`NetSim::pop`] + [`NetSim::advance_to`] in one call (tests and
    /// simple consumers).
    pub fn pop_advance(&mut self) -> Option<Event> {
        let (at, event) = self.pop()?;
        self.advance_to(at);
        Some(event)
    }

    /// Bookkeeping for a resolved stale read: counts any lag, and counts
    /// + traces reads forced past the staleness budget (the
    /// silent-neighbour fallback). Shared by the async and cluster
    /// runtimes so their `NetCounters` mean the same thing.
    pub fn note_stale_read(&mut self, node: NodeId, nbr: NodeId, ideal: u64,
                           used: u64, stale: u64) {
        if used < ideal {
            self.counters.stale_reads += 1;
            if used + stale < ideal {
                self.counters.fallback_reads += 1;
                self.record(TraceKind::Fallback { node, nbr, ideal, used });
            }
        }
    }

    /// Bookkeeping for a delivery the consumer accepted.
    pub fn note_delivered(&mut self, src: NodeId, dst: NodeId, payload: &Payload) {
        self.counters.delivered += 1;
        self.record(TraceKind::Deliver {
            src,
            dst,
            what: payload.kind_name(),
            stamp: payload.stamp(),
        });
    }

    /// Bookkeeping for a delivery whose destination was dead.
    pub fn note_dead_delivery(&mut self, src: NodeId, dst: NodeId, payload: &Payload) {
        self.counters.dropped_dead += 1;
        self.record(TraceKind::DropDead { src, dst, stamp: payload.stamp() });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn theta(stamp: u64) -> Payload {
        Payload::Theta { stamp, theta: vec![1.0, 2.0] }
    }

    #[test]
    fn events_pop_in_time_then_seq_order() {
        let mut sim = NetSim::new(0, FaultPlan::none(), true);
        sim.schedule(5, Event::Wake { node: 0, epoch: 0 });
        sim.schedule(2, Event::Wake { node: 1, epoch: 0 });
        sim.schedule(2, Event::Wake { node: 2, epoch: 0 });
        assert_eq!(sim.pop_advance(), Some(Event::Wake { node: 1, epoch: 0 }));
        assert_eq!(sim.pop_advance(), Some(Event::Wake { node: 2, epoch: 0 }),
                   "same tick: schedule order wins");
        assert_eq!(sim.now(), 2);
        assert_eq!(sim.pop_advance(), Some(Event::Wake { node: 0, epoch: 0 }));
        assert_eq!(sim.now(), 5);
        assert_eq!(sim.pop_advance(), None);
    }

    #[test]
    fn ideal_link_delivers_instantly_and_losslessly() {
        let mut sim = NetSim::new(7, FaultPlan::none(), true);
        for k in 0..50 {
            sim.send(0, 1, theta(k), false);
        }
        let mut got = 0;
        while let Some(ev) = sim.pop_advance() {
            match ev {
                Event::Deliver { src: 0, dst: 1, payload, dup: false, ctx: _ } => {
                    assert_eq!(payload.stamp(), got, "FIFO at fixed latency");
                    got += 1;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, 50);
        assert_eq!(sim.now(), 0, "zero latency keeps the clock at 0");
    }

    #[test]
    fn lossy_link_drops_a_plausible_fraction() {
        let plan = FaultPlan {
            link: LinkModel { base: 1, jitter: 3, loss: 0.3, dup: 0.0 },
            ..FaultPlan::none()
        };
        let mut sim = NetSim::new(3, plan, false);
        for k in 0..2000 {
            sim.send(0, 1, Payload::Eta { stamp: k, eta: 1.0 }, false);
        }
        let dropped = sim.counters.dropped_loss;
        assert!((400..800).contains(&(dropped as usize)), "dropped {dropped}");
        let mut delivered = 0;
        while sim.pop_advance().is_some() {
            delivered += 1;
        }
        assert_eq!(delivered as u64 + dropped, 2000);
    }

    #[test]
    fn duplication_schedules_two_copies() {
        let plan = FaultPlan {
            link: LinkModel { base: 0, jitter: 0, loss: 0.0, dup: 1.0 },
            ..FaultPlan::none()
        };
        let mut sim = NetSim::new(1, plan, true);
        sim.send(0, 1, theta(0), false);
        let a = sim.pop_advance().unwrap();
        let b = sim.pop_advance().unwrap();
        match (a, b) {
            (Event::Deliver { dup: d1, .. }, Event::Deliver { dup: d2, .. }) => {
                assert!(!d1 && d2, "original then duplicate");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(sim.counters.duplicated, 1);
    }

    #[test]
    fn partition_cuts_only_across_groups_during_window() {
        let plan = FaultPlan {
            partitions: vec![Partition { start: 10, end: 20, group: vec![0, 1] }],
            ..FaultPlan::none()
        };
        let mut sim = NetSim::new(0, plan, false);
        // before the window: crosses fine
        sim.send(0, 2, theta(0), false);
        // inside the window: cross-cut dropped, intra-group passes
        sim.schedule(10, Event::Wake { node: 0, epoch: 0 });
        while let Some(ev) = sim.pop_advance() {
            if matches!(ev, Event::Wake { .. }) {
                break;
            }
        }
        assert_eq!(sim.now(), 10);
        sim.send(0, 2, theta(1), false);
        sim.send(0, 1, theta(2), false);
        assert_eq!(sim.counters.dropped_partition, 1);
        // reliable handshake pierces the partition
        sim.send(2, 0, theta(3), true);
        assert_eq!(sim.counters.dropped_partition, 1);
    }

    #[test]
    fn churn_plan_preschedules_events() {
        let plan = FaultPlan {
            churn: vec![
                ChurnEvent::Leave { at: 8, node: 3 },
                ChurnEvent::Join { at: 4, node: 5 },
            ],
            ..FaultPlan::none()
        };
        let mut sim = NetSim::new(0, plan, true);
        assert_eq!(sim.pop_advance(), Some(Event::Join { node: 5 }));
        assert_eq!(sim.now(), 4);
        assert_eq!(sim.pop_advance(), Some(Event::Leave { node: 3 }));
        assert_eq!(sim.now(), 8);
    }

    #[test]
    fn identical_seeds_identical_traces() {
        let run = || {
            let plan = FaultPlan {
                link: LinkModel { base: 2, jitter: 5, loss: 0.2, dup: 0.1 },
                ..FaultPlan::none()
            };
            let mut sim = NetSim::new(42, plan, true);
            for k in 0..200 {
                sim.send((k % 3) as usize, ((k + 1) % 3) as usize, theta(k), false);
            }
            while sim.pop_advance().is_some() {}
            let trace = sim.take_trace();
            (trace, sim.counters)
        };
        let (t1, c1) = run();
        let (t2, c2) = run();
        assert_eq!(t1, t2);
        assert_eq!(c1, c2);
        assert_eq!(c1.trace_dropped, 0, "scenario stays under the default cap");
    }

    #[test]
    fn bounded_trace_evicts_deterministically() {
        let run = || {
            let plan = FaultPlan {
                link: LinkModel { base: 2, jitter: 5, loss: 0.2, dup: 0.1 },
                ..FaultPlan::none()
            };
            let mut sim = NetSim::new(42, plan, true);
            sim.set_trace_capacity(64); // force eviction: ~400 events ahead
            for k in 0..200 {
                sim.send((k % 3) as usize, ((k + 1) % 3) as usize, theta(k), false);
            }
            while sim.pop_advance().is_some() {}
            let trace = sim.take_trace();
            (trace, sim.counters)
        };
        let (t1, c1) = run();
        let (t2, c2) = run();
        assert_eq!(t1.len(), 64, "retained exactly the capacity");
        assert!(c1.trace_dropped > 0, "overflow must be accounted");
        assert_eq!(t1, t2, "evicted trace still replays identically");
        assert_eq!(c1, c2);
        // the retained suffix is chronological
        assert!(t1.windows(2).all(|w| w[0].at <= w[1].at));
    }
}
