//! Asynchronous, fault-tolerant ADMM over the simulated network.
//!
//! ## Protocol
//!
//! Each node runs the same two-phase round structure as the synchronous
//! engines, but gated on *messages* instead of barriers. Round `t` of
//! node `i`:
//!
//! 1. **Solve** (phase A): needs every live neighbour's θ with stamp
//!    "ideally `t`" — computes θ_i^{t+1} via [`LocalSolver::solve_into`]
//!    with the node's own η^t, broadcasts `Theta{stamp: t+1}`.
//! 2. **Reduce** (phase B): needs neighbour θ stamped ideally `t+1` and
//!    neighbour η stamped ideally `t` — λ update with the symmetrized
//!    η̄ = ½(η_ij + η_ji), local residuals, objectives, and a round-`t`
//!    *contribution* to the global fold.
//! 3. **Scheme** (phase C): penalty update via [`PenaltyScheme`] (the RB
//!    reference scheme first waits for the round-`t` fold, since it reads
//!    global residuals), broadcasts `Eta{stamp: t+1}`, hands the fresh η
//!    to the [`TopologyController`].
//!
//! ## Bounded staleness and the silent-neighbour fallback
//!
//! A read with ideal stamp `r` accepts the largest cached stamp `≤ r`,
//! and a phase may *start* once every live neighbour has some stamp
//! `≥ r − max_staleness` (with `max_staleness = 0` this is the exact
//! lock-step schedule of the synchronous engines). When a neighbour goes
//! silent — loss streak, partition — the node arms a `silence_timeout`
//! wake-up; when it fires, the node proceeds anyway with the best cached
//! value (the *stale η̄/θ̄ fallback*; counted in
//! [`crate::metrics::NetCounters::fallback_reads`] and traced). The
//! one-shot join handshake is delivered reliably, so any slot that was
//! ever live has a cache entry and forced progress is always possible.
//!
//! ## Zero-fault parity (the oracle contract)
//!
//! With [`FaultPlan::none`] and `max_staleness = 0`, every read resolves
//! to its exact ideal stamp, folds run over all n nodes in id order with
//! the same floating-point accumulation order as [`Engine::step`], and θ⁰
//! is seeded from the identical shared RNG stream — so the per-round
//! trajectory (θ, λ, η, every [`IterStats`] field) is **bit-for-bit**
//! equal to the sequential engine's, for all seven schemes. The tests in
//! `net::tests` assert this on Ring and Star.
//!
//! ## Dynamic topology
//!
//! Scripted churn events pop out of the simulator queue; the
//! [`TopologyController`] applies them to the run's [`LiveView`]. A dead
//! neighbour's slot drops out of η̄ normalization and the solve/λ loops
//! (live-degree semantics; a fully isolated node degenerates to η̄ = 0
//! exactly like the synchronous runtimes). A joining node enters at the
//! current round frontier with a reliable state handshake in both
//! directions. Global folds expect a contribution from every node that
//! was live for that round — nodes that leave stop being expected, nodes
//! that join are only expected from their start round on.

use std::collections::BTreeMap;

use crate::consensus::LocalSolver;
use crate::graph::{Graph, LiveView, NodeId};
use crate::kernel::{DualPolicy, FlatRound, KernelScratch, NodeKernel, SlotView,
                    StopTracker};
use crate::metrics::{IterStats, NetCounters, Recorder};
use crate::obs::{MetricsRegistry, Phase as ObsPhase, RoundRow, RoundSeries,
                 RuntimeProbes, Timeline};
use crate::penalty::{SchemeKind, SchemeParams};
use crate::util::rng::Pcg;

use super::sim::{Event, FaultPlan, NetSim, Payload, Ticks, TraceEvent, TraceKind};
use super::topology::{ActivityConfig, TopologyController};
use super::transport::send_traced;

#[cfg(doc)]
use crate::consensus::Engine;

/// Async-runner configuration (mirrors [`crate::consensus::EngineConfig`]
/// plus the network knobs).
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    pub tol: f64,
    pub patience: usize,
    pub warmup: usize,
    pub max_iters: usize,
    pub seed: u64,
    /// How many rounds behind its ideal stamp a neighbour read may lag
    /// before the node blocks. 0 = exact lock-step (the parity setting).
    /// Nodes free-run at the budget, so keep this ≤ 1: systematic lag ≥ 2
    /// destabilizes the dual accumulation on the standard workloads (see
    /// the module-level "Stability boundary" notes).
    pub max_staleness: u64,
    /// Virtual ticks a blocked node waits before forcing progress on the
    /// best cached values. 0 disables the fallback (pure blocking — only
    /// safe under zero loss).
    pub silence_timeout: Ticks,
    /// Enable the NAP effective-topology rule (edge masking by penalty
    /// influence). `None` keeps the physical topology fixed up to churn.
    pub activity: Option<ActivityConfig>,
    /// Lag-aware λ damping: scale each slot's dual increment by
    /// `1/(1 + lag)` where `lag` is how many rounds the resolved θ^{t+1}
    /// read trailed its ideal stamp. Stale dual steps are the positive
    /// feedback that destabilizes budgets ≥ 2 (see the module docs'
    /// stability boundary); damping shrinks exactly those steps. Off by
    /// default — and bit-identical to the undamped runner whenever no
    /// read lags (zero faults, or `max_staleness = 0` without forced
    /// fallbacks).
    pub lag_damping: bool,
    /// The complementary kernel policy: *skip* the λ increment entirely
    /// for a slot whose θ^{t+1} read was a forced fallback (resolved more
    /// than `max_staleness` rounds stale) — the θ still feeds the
    /// neighbour mean, only the multiplier is protected from the
    /// unbounded generation mismatch. Off by default and bit-identical
    /// whenever no read falls back; composes with `lag_damping` (skipped
    /// beyond the budget, damped within it). See the module docs'
    /// "Stability boundary" for the tradeoff against damping.
    pub skip_lambda_on_fallback: bool,
    /// Record the replayable event trace (tests/debugging; counters are
    /// always kept).
    pub tracing: bool,
    /// Flight-recorder capacity when tracing (0 = keep nothing, count
    /// every event as dropped).
    pub trace_capacity: usize,
    /// enable phase-span timing ([`crate::obs`]); counters/gauges are
    /// always recorded
    pub obs: bool,
    /// record the causal round timeline ([`crate::obs::Timeline`]):
    /// per-frame send/deliver events, per-phase durations, fold commits
    pub timeline: bool,
    /// record the per-round convergence series
    /// ([`crate::obs::RoundSeries`]): committed [`IterStats`] plus live
    /// node/edge counts, one row per fold
    pub series: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            scheme: SchemeKind::Fixed,
            params: SchemeParams::default(),
            tol: 1e-3,
            patience: 3,
            warmup: 5,
            max_iters: 1000,
            seed: 0,
            max_staleness: 0,
            silence_timeout: 64,
            activity: None,
            lag_damping: false,
            skip_lambda_on_fallback: false,
            tracing: true,
            trace_capacity: crate::obs::DEFAULT_TRACE_CAPACITY,
            obs: false,
            timeline: false,
            series: false,
        }
    }
}

impl NetConfig {
    /// The kernel [`DualPolicy`] this configuration selects.
    fn dual_policy(&self) -> DualPolicy {
        DualPolicy {
            lag_damping: self.lag_damping,
            skip_beyond: self.skip_lambda_on_fallback.then_some(self.max_staleness),
        }
    }
}

/// Outcome of an async run.
#[derive(Debug)]
pub struct NetReport {
    /// Completed global folds (= engine iterations at zero faults).
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
    /// Final per-node parameters: the θ each node carried at the last
    /// fold it contributed to (θ⁰ for nodes that never ran).
    pub thetas: Vec<Vec<f64>>,
    /// Virtual time consumed (ticks).
    pub virtual_time: Ticks,
    pub counters: NetCounters,
    /// Replayable event trace (empty when `tracing` was off).
    pub trace: Vec<TraceEvent>,
    /// Causal timeline events (empty unless `cfg.timeline` or the global
    /// timeline sink was enabled).
    pub timeline: Vec<crate::obs::TlEvent>,
    /// Ring-overwritten timeline events (capacity pressure).
    pub timeline_dropped: u64,
    /// Per-round committed-stats rows (empty unless `cfg.series` or the
    /// global series sink was enabled).
    pub series: Vec<RoundRow>,
    /// Series rows lost to decimation/capping.
    pub series_dropped: u64,
    /// Final liveness per node.
    pub live: Vec<bool>,
    /// unified telemetry ([`crate::obs`]): per-phase histograms (when
    /// `cfg.obs`), absorbed net counters and trace retention stats
    pub obs: MetricsRegistry,
}

// ---------------------------------------------------------------------------

/// Stamp-indexed per-slot neighbour cache. Reads resolve to the largest
/// stamp ≤ ideal (falling forward to the smallest stamp > ideal only when
/// nothing older exists — a node that joined at a later round than the
/// reader's ideal); entries below the resolved stamp are pruned, the
/// newest entry is never dropped.
#[derive(Debug, Default)]
struct SlotCache {
    theta: BTreeMap<u64, Vec<f64>>,
    eta: BTreeMap<u64, f64>,
}

impl SlotCache {
    fn theta_ready(&self, ideal: u64, stale: u64) -> bool {
        self.theta
            .range(ideal.saturating_sub(stale)..)
            .next()
            .is_some()
    }

    fn eta_ready(&self, ideal: u64, stale: u64) -> bool {
        self.eta.range(ideal.saturating_sub(stale)..).next().is_some()
    }

    /// Resolve a θ read to its stamp (see type docs), pruning older
    /// entries. Caller guarantees non-emptiness; pair with
    /// [`SlotCache::theta_at`] to borrow the value (the two-step shape
    /// lets the staleness accounting run between resolve and use).
    fn resolve_theta(&mut self, ideal: u64) -> u64 {
        let best = self.theta.range(..=ideal).next_back().map(|(&s, _)| s);
        match best {
            Some(s) => {
                self.theta.retain(|&k, _| k >= s);
                s
            }
            None => *self.theta.keys().next().expect("cache checked nonempty"),
        }
    }

    fn theta_at(&self, stamp: u64) -> &[f64] {
        self.theta.get(&stamp).expect("resolved").as_slice()
    }

    /// Resolve a θ read (see type docs). Caller guarantees non-emptiness.
    fn read_theta(&mut self, ideal: u64) -> (u64, &[f64]) {
        let s = self.resolve_theta(ideal);
        (s, self.theta.get(&s).expect("resolved").as_slice())
    }

    fn read_eta(&mut self, ideal: u64) -> (u64, f64) {
        let best = self.eta.range(..=ideal).next_back().map(|(&s, _)| s);
        match best {
            Some(s) => {
                self.eta.retain(|&k, _| k >= s);
                (s, *self.eta.get(&s).expect("retained"))
            }
            None => {
                let (&s, &v) = self.eta.iter().next().expect("cache checked nonempty");
                (s, v)
            }
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// waiting to run phase A of round `t`
    Solve,
    /// waiting to run phase B of round `t`
    Reduce,
    /// phase B done; phase C pending (RB waits for the round fold here)
    FoldWait,
    /// scripted joiner that has not activated yet
    Dormant,
    /// left the network
    Dead,
    /// finished `max_iters` rounds
    Done,
}

struct NodeRt<S> {
    solver: S,
    /// λ/η/scheme/residual state — the shared protocol kernel
    kernel: NodeKernel,
    /// θ^t before phase A of round t; θ^{t+1} after
    theta: Vec<f64>,
    theta_next: Vec<f64>,
    t: u64,
    phase: Phase,
    caches: Vec<SlotCache>,
    // silence-timeout bookkeeping
    wake_epoch: u64,
    timeout_armed: bool,
    /// first round this node participates in (u64::MAX while dormant)
    start_round: u64,
    /// the scheme reads folded global residuals (RB) → phase C must wait
    /// for the round's fold
    needs_globals: bool,
}

/// One node's round-`t` input to the global fold. Carries the raw η and θ
/// vectors so the fold can reproduce the sequential engine's flat
/// accumulation order bit-for-bit (a pre-reduced per-node partial would
/// regroup the floating-point sums).
struct Contribution {
    f_self: f64,
    primal: f64,
    dual: f64,
    etas: Vec<f64>,
    theta: Vec<f64>,
}

struct FoldState {
    /// round → per-node contribution slots
    pending: BTreeMap<u64, Vec<Option<Contribution>>>,
    next_fold: u64,
    /// flat node-order round accumulator (the engine's oracle arithmetic)
    flat: FlatRound,
    /// the shared stop state machine (checker + recorder + verdict memory)
    tracker: StopTracker,
    /// θ each node carried at the last fold it contributed to
    latest_committed: Vec<Vec<f64>>,
    /// latest folded (global_primal, global_dual) — what RB observes
    globals: (f64, f64),
}

/// Application-metric hook invoked at every completed fold with
/// `(round, latest committed θ per node, per-node liveness)` — the
/// unified [`crate::kernel::AppMetricHook`] surface, boxed. The θ
/// snapshot is *async-friendly*: a dead, dormant or lagging node's slot
/// holds the last value it committed (θ⁰ if it never ran), and the
/// liveness slice says which slots are current — so metrics like the
/// D-PPCA subspace angle can run under loss and churn without the hook
/// having to know the protocol.
pub type AppMetricHook = Box<dyn crate::kernel::AppMetricHook>;

/// The asynchronous runner (see module docs).
pub struct AsyncRunner<S: LocalSolver> {
    cfg: NetConfig,
    ctrl: TopologyController,
    sim: NetSim,
    nodes: Vec<NodeRt<S>>,
    scratch: KernelScratch,
    /// per-slot liveness mask scratch (phase C observations)
    mask_scratch: Vec<bool>,
    fold: FoldState,
    /// deferred wake-ups (topology toggles, fold completions)
    pending_wakes: Vec<NodeId>,
    foldwait_dirty: bool,
    stopped: bool,
    metric: Option<AppMetricHook>,
    /// unified telemetry: registered at construction, recorded via
    /// `Copy` ids on the hot path (clock reads only when `cfg.obs`)
    obs: MetricsRegistry,
    probes: RuntimeProbes,
    /// causal round timeline (bounded ring; no-op when disabled)
    timeline: Timeline,
    /// per-round committed-stats series (no-op when disabled)
    series: RoundSeries,
}

impl<S: LocalSolver> AsyncRunner<S> {
    /// Build a runner; one solver per graph node (like [`Engine::new`] —
    /// θ⁰ seeding is shared-stream in id order, so the zero-fault run is
    /// bit-identical to the engine's).
    pub fn new(graph: Graph, mut solvers: Vec<S>, cfg: NetConfig, plan: FaultPlan)
               -> AsyncRunner<S> {
        let n = graph.len();
        assert_eq!(n, solvers.len(), "one solver per node");
        assert!(!solvers.is_empty());
        let dim = solvers[0].dim();
        assert!(solvers.iter().all(|s| s.dim() == dim), "homogeneous dims");
        for ev in &plan.churn {
            let node = match *ev {
                super::sim::ChurnEvent::Join { node, .. }
                | super::sim::ChurnEvent::Leave { node, .. } => node,
            };
            assert!(node < n, "churn event on node {node} out of range");
        }
        assert!(plan.initially_dormant.iter().all(|&i| i < n),
                "dormant node out of range");

        // θ⁰ from the engine's shared stream, id order — parity-critical
        let mut rng = Pcg::new(cfg.seed, 0xE191E);
        let thetas: Vec<Vec<f64>> = solvers
            .iter_mut()
            .map(|s| {
                let th = s.initial_param(&mut rng);
                assert_eq!(th.len(), dim);
                th
            })
            .collect();

        let dormant = plan.initially_dormant.clone();
        let mut ctrl = TopologyController::new(graph, cfg.activity);
        for &i in &dormant {
            ctrl.view_mut().set_node(i, false);
        }
        let graph_ref = ctrl.view().graph();
        let mut max_deg = 0usize;
        let mut nodes: Vec<NodeRt<S>> = Vec::with_capacity(n);
        for (i, (solver, theta)) in solvers.drain(..).zip(thetas).enumerate() {
            let deg = graph_ref.degree(i);
            max_deg = max_deg.max(deg);
            let is_dormant = dormant.contains(&i);
            let phase = if is_dormant {
                Phase::Dormant
            } else if cfg.max_iters == 0 {
                Phase::Done
            } else {
                Phase::Solve
            };
            let kernel = NodeKernel::new(cfg.scheme, cfg.params, deg, dim);
            let needs_globals = kernel.needs_global_residuals();
            nodes.push(NodeRt {
                solver,
                kernel,
                theta,
                theta_next: vec![0.0; dim],
                t: 0,
                phase,
                caches: (0..deg).map(|_| SlotCache::default()).collect(),
                wake_epoch: 0,
                timeout_armed: false,
                start_round: if is_dormant { u64::MAX } else { 0 },
                needs_globals,
            });
        }
        let mut sim = NetSim::new(cfg.seed, plan, cfg.tracing);
        if cfg.tracing {
            sim.set_trace_capacity(cfg.trace_capacity);
        }
        let mut obs =
            MetricsRegistry::new(cfg.obs || crate::obs::global_spans_enabled());
        let probes = RuntimeProbes::register(&mut obs);
        let timeline =
            Timeline::new(cfg.timeline || crate::obs::global_timeline_enabled());
        let series =
            RoundSeries::new(cfg.series || crate::obs::global_series_enabled());
        let latest_committed = nodes.iter().map(|nd| nd.theta.clone()).collect();
        AsyncRunner {
            obs,
            probes,
            timeline,
            series,
            scratch: KernelScratch::new(dim, max_deg),
            mask_scratch: Vec::with_capacity(max_deg),
            fold: FoldState {
                pending: BTreeMap::new(),
                next_fold: 0,
                flat: FlatRound::new(dim),
                tracker: StopTracker::new(dim, cfg.tol, cfg.patience, cfg.warmup,
                                          cfg.max_iters, cfg.params.eta0),
                latest_committed,
                globals: (f64::INFINITY, f64::INFINITY),
            },
            pending_wakes: Vec::new(),
            foldwait_dirty: false,
            stopped: false,
            metric: None,
            nodes,
            ctrl,
            sim,
            cfg,
        }
    }

    /// Attach an application-metric hook — the unified
    /// [`crate::kernel::AppMetricHook`] surface (any
    /// `FnMut(round, θ, live) -> f64` closure qualifies); its value lands
    /// in [`IterStats::app_error`] per completed fold.
    pub fn with_app_metric(
        mut self,
        metric: impl crate::kernel::AppMetricHook + 'static,
    ) -> Self {
        self.metric = Some(Box::new(metric));
        self
    }

    /// Drive the simulation to completion and report.
    pub fn run(mut self) -> NetReport {
        self.init_handshake();
        let n = self.nodes.len();
        for i in 0..n {
            self.try_advance(i, false);
        }
        self.drain();

        while !self.stopped {
            let Some((at, event)) = self.sim.pop() else { break };
            // stale wake-ups are skipped without advancing the clock, so
            // virtual time reflects real activity only
            if let Event::Wake { node, epoch } = event {
                let nd = &self.nodes[node];
                if epoch != nd.wake_epoch
                    || matches!(nd.phase, Phase::Dormant | Phase::Dead | Phase::Done)
                {
                    continue;
                }
            }
            self.sim.advance_to(at);
            match event {
                Event::Deliver { src, dst, payload, dup: _, ctx } => {
                    if self.timeline.enabled() {
                        self.timeline.recv(at, dst, ctx, payload.kind_name());
                    }
                    self.on_deliver(src, dst, payload);
                }
                Event::Wake { node, epoch: _ } => {
                    self.sim.counters.timeouts += 1;
                    self.nodes[node].timeout_armed = false;
                    self.try_advance(node, true);
                }
                // auxiliary timers belong to the cluster runtime; this
                // consumer never arms one
                Event::Timer { .. } => {}
                Event::Join { node } => self.on_join(node),
                Event::Leave { node } => self.on_leave(node),
            }
            self.drain();
        }
        self.finish()
    }

    // -- event handlers -----------------------------------------------------

    fn init_handshake(&mut self) {
        let n = self.nodes.len();
        for i in 0..n {
            if !self.ctrl.view().node_live(i) {
                continue;
            }
            self.broadcast_state(i, 0, 0);
        }
    }

    /// Reliably send node i's current θ (stamped `ts`) and η (stamped
    /// `es`) to every live neighbour — the join/init handshake.
    fn broadcast_state(&mut self, i: NodeId, ts: u64, es: u64) {
        let deg = self.ctrl.view().graph().degree(i);
        for slot in 0..deg {
            if !self.ctrl.view().slot_live(i, slot) {
                continue;
            }
            let j = self.ctrl.view().graph().neighbors(i)[slot];
            let theta = self.nodes[i].theta.clone();
            let eta = self.nodes[i].kernel.etas[slot];
            send_traced(&mut self.sim, &mut self.timeline, i, j,
                        Payload::Theta { stamp: ts, theta }, true);
            send_traced(&mut self.sim, &mut self.timeline, i, j,
                        Payload::Eta { stamp: es, eta }, true);
        }
    }

    fn on_deliver(&mut self, src: NodeId, dst: NodeId, payload: Payload) {
        if matches!(self.nodes[dst].phase, Phase::Dormant | Phase::Dead) {
            self.sim.note_dead_delivery(src, dst, &payload);
            return;
        }
        let slot = self
            .ctrl
            .view()
            .graph()
            .edge_slot(dst, src)
            .expect("messages travel existing edges");
        self.sim.note_delivered(src, dst, &payload);
        let cache = &mut self.nodes[dst].caches[slot];
        match payload {
            Payload::Theta { stamp, theta } => {
                cache.theta.insert(stamp, theta);
            }
            Payload::Eta { stamp, eta } => {
                cache.eta.insert(stamp, eta);
            }
            // cluster (machine-level) payloads never travel the per-node
            // transport — mirror of the cluster runner ignoring Theta/Eta
            _ => {}
        }
        self.try_advance(dst, false);
    }

    fn on_join(&mut self, node: NodeId) {
        // a rejoiner (left earlier, phase Dead) may have been ahead of the
        // surviving peers when it left; never restart below one past its
        // own last round, or it would contribute the same round twice
        let rejoin_floor = if self.nodes[node].phase == Phase::Dead {
            self.nodes[node].t + 1
        } else {
            0
        };
        if !self.ctrl.apply_join(node, &mut self.sim) {
            return;
        }
        // enter at the current round frontier: one past the most advanced
        // live peer, and never below the fold cursor
        let frontier = self
            .nodes
            .iter()
            .enumerate()
            .filter(|&(j, nd)| {
                j != node && !matches!(nd.phase, Phase::Dormant | Phase::Dead)
            })
            .map(|(_, nd)| nd.t + 1)
            .max()
            .unwrap_or(0)
            .max(self.fold.next_fold)
            .max(rejoin_floor);
        let start = frontier.min(self.cfg.max_iters as u64);
        {
            let nd = &mut self.nodes[node];
            nd.t = start;
            nd.start_round = start;
            nd.phase = if start >= self.cfg.max_iters as u64 {
                Phase::Done
            } else {
                Phase::Solve
            };
        }
        // two-way reliable state handshake so neither side starts from an
        // empty cache
        self.broadcast_state(node, start, start);
        let deg = self.ctrl.view().graph().degree(node);
        for slot in 0..deg {
            if !self.ctrl.view().slot_live(node, slot) {
                continue;
            }
            let j = self.ctrl.view().graph().neighbors(node)[slot];
            let (ts, es) = self.current_stamps(j);
            let rev = self
                .ctrl
                .view()
                .graph()
                .edge_slot(j, node)
                .expect("graph symmetry");
            let theta = self.nodes[j].theta.clone();
            let eta = self.nodes[j].kernel.etas[rev];
            send_traced(&mut self.sim, &mut self.timeline, j, node,
                        Payload::Theta { stamp: ts, theta }, true);
            send_traced(&mut self.sim, &mut self.timeline, j, node,
                        Payload::Eta { stamp: es, eta }, true);
            self.pending_wakes.push(j);
        }
        self.try_advance(node, false);
    }

    /// Stamps describing what a node's `theta`/`etas` fields currently
    /// hold (phase-dependent; see the protocol in the module docs).
    fn current_stamps(&self, i: NodeId) -> (u64, u64) {
        let nd = &self.nodes[i];
        match nd.phase {
            Phase::Reduce | Phase::FoldWait => (nd.t + 1, nd.t),
            _ => (nd.t, nd.t),
        }
    }

    fn on_leave(&mut self, node: NodeId) {
        if !self.ctrl.apply_leave(node, &mut self.sim) {
            return;
        }
        self.nodes[node].phase = Phase::Dead;
        // fold expectations shrank; blocked neighbours may be ready now
        let deg = self.ctrl.view().graph().degree(node);
        for slot in 0..deg {
            let j = self.ctrl.view().graph().neighbors(node)[slot];
            if !matches!(self.nodes[j].phase, Phase::Dormant | Phase::Dead) {
                self.pending_wakes.push(j);
            }
        }
        self.try_folds();
    }

    // -- the node state machine --------------------------------------------

    fn try_advance(&mut self, i: NodeId, mut force: bool) {
        loop {
            if self.stopped {
                return;
            }
            match self.nodes[i].phase {
                Phase::Dormant | Phase::Dead | Phase::Done => return,
                Phase::Solve => {
                    let span = self.obs.span();
                    let ok = phase_a(&mut self.nodes[i], i, self.ctrl.view(),
                                     &mut self.scratch, &mut self.sim,
                                     &mut self.timeline, &self.cfg, force);
                    let ns = self.obs.end(self.probes.solve, span);
                    if !ok {
                        self.arm_timeout(i);
                        return;
                    }
                    if self.timeline.enabled() {
                        let t = self.nodes[i].t;
                        self.timeline
                            .phase(self.sim.now(), i, t, ObsPhase::Solve, ns);
                    }
                    self.nodes[i].phase = Phase::Reduce;
                }
                Phase::Reduce => {
                    let span = self.obs.span();
                    let contrib = phase_b(&mut self.nodes[i], i, self.ctrl.view(),
                                          &mut self.scratch, &mut self.sim,
                                          &self.cfg, force);
                    let ns = self.obs.end(self.probes.reduce, span);
                    let Some(contrib) = contrib else {
                        self.arm_timeout(i);
                        return;
                    };
                    let t = self.nodes[i].t;
                    if self.timeline.enabled() {
                        self.timeline
                            .phase(self.sim.now(), i, t, ObsPhase::Reduce, ns);
                    }
                    self.nodes[i].phase = Phase::FoldWait;
                    self.record_contribution(t, i, contrib);
                    self.try_folds();
                    if self.stopped {
                        return;
                    }
                }
                Phase::FoldWait => {
                    let t = self.nodes[i].t;
                    if self.nodes[i].needs_globals && self.fold.next_fold <= t {
                        return; // woken by the fold (no timeout: folds
                                // complete as peers progress)
                    }
                    let span = self.obs.span();
                    let toggled = phase_c(&mut self.nodes[i], i, &mut self.ctrl,
                                          &mut self.sim, &mut self.timeline,
                                          &self.cfg, self.fold.globals,
                                          &mut self.mask_scratch);
                    let ns = self.obs.end(self.probes.observe, span);
                    if self.timeline.enabled() {
                        self.timeline
                            .phase(self.sim.now(), i, t, ObsPhase::Observe, ns);
                    }
                    for (a, b) in toggled {
                        self.pending_wakes.push(a);
                        self.pending_wakes.push(b);
                    }
                    let nd = &mut self.nodes[i];
                    nd.t += 1;
                    nd.phase = if nd.t >= self.cfg.max_iters as u64 {
                        Phase::Done
                    } else {
                        Phase::Solve
                    };
                }
            }
            // progress happened: invalidate any armed timeout
            let nd = &mut self.nodes[i];
            nd.wake_epoch += 1;
            nd.timeout_armed = false;
            force = false;
        }
    }

    fn arm_timeout(&mut self, i: NodeId) {
        let timeout = self.cfg.silence_timeout;
        if timeout == 0 || self.nodes[i].timeout_armed {
            return;
        }
        self.nodes[i].timeout_armed = true;
        let epoch = self.nodes[i].wake_epoch;
        let at = self.sim.now() + timeout;
        self.sim.schedule(at, Event::Wake { node: i, epoch });
    }

    /// Process deferred wake-ups until quiescent.
    fn drain(&mut self) {
        loop {
            if self.stopped {
                return;
            }
            if let Some(i) = self.pending_wakes.pop() {
                if !matches!(self.nodes[i].phase,
                             Phase::Dormant | Phase::Dead | Phase::Done) {
                    self.try_advance(i, false);
                }
                continue;
            }
            if self.foldwait_dirty {
                self.foldwait_dirty = false;
                for i in 0..self.nodes.len() {
                    if self.nodes[i].phase == Phase::FoldWait {
                        self.try_advance(i, false);
                    }
                }
                continue;
            }
            return;
        }
    }

    // -- folds ---------------------------------------------------------------

    fn record_contribution(&mut self, round: u64, i: NodeId, c: Contribution) {
        let n = self.nodes.len();
        let slots = self
            .fold
            .pending
            .entry(round)
            .or_insert_with(|| (0..n).map(|_| None).collect());
        debug_assert!(slots[i].is_none(), "one contribution per node per round");
        slots[i] = Some(c);
    }

    /// Whether node `i` owes a contribution to round `r`.
    fn expects(&self, i: NodeId, r: u64) -> bool {
        let nd = &self.nodes[i];
        !matches!(nd.phase, Phase::Dead | Phase::Dormant) && nd.start_round <= r
    }

    fn try_folds(&mut self) {
        let n = self.nodes.len();
        while !self.stopped {
            let r = self.fold.next_fold;
            if r >= self.cfg.max_iters as u64 {
                break;
            }
            let Some(slots) = self.fold.pending.get(&r) else { break };
            let complete = (0..n).all(|i| slots[i].is_some() || !self.expects(i, r));
            if !complete {
                break;
            }
            let slots = self.fold.pending.remove(&r).expect("present");
            self.do_fold(r, slots);
        }
        // contributions for rounds before the cursor can never fold (their
        // owner died mid-round); drop them so memory stays bounded
        let cursor = self.fold.next_fold;
        self.fold.pending.retain(|&r, _| r >= cursor);
    }

    /// Combine a completed round in node-id order with the sequential
    /// engine's exact accumulation order (the kernel's flat
    /// [`FlatRound`] — no per-shard regrouping), derive the verdict and
    /// commit through the shared [`StopTracker`].
    fn do_fold(&mut self, r: u64, slots: Vec<Option<Contribution>>) {
        let span = self.obs.span();
        self.fold.flat.begin();
        for c in slots.iter().flatten() {
            self.fold.flat.add_node(c.f_self, c.primal, c.dual, &c.etas);
            self.fold.flat.add_theta(&c.theta);
        }
        if self.fold.flat.count == 0 {
            return; // nothing to fold (all contributors died)
        }
        self.fold.flat.finish_mean();
        for c in slots.iter().flatten() {
            self.fold.flat.add_spread(&c.theta);
        }
        let g = self.fold.tracker.round_flat(&self.fold.flat);

        for (i, c) in slots.into_iter().enumerate() {
            if let Some(c) = c {
                self.fold.latest_committed[i] = c.theta;
            }
        }

        // app metric over the committed snapshot (stale slots keep their
        // last committed value; the liveness slice marks them)
        let app_error = match self.metric.as_mut() {
            Some(metric) => {
                let n = self.fold.latest_committed.len();
                let live: Vec<bool> =
                    (0..n).map(|i| self.ctrl.view().node_live(i)).collect();
                metric.measure(r as usize, &self.fold.latest_committed, &live)
            }
            None => 0.0,
        };

        let stats = IterStats {
            iter: r as usize,
            objective: g.objective,
            max_primal: g.max_primal,
            max_dual: g.max_dual,
            mean_eta: g.mean_eta,
            min_eta: g.min_eta,
            max_eta: g.max_eta,
            app_error,
        };
        let stop = self.fold.tracker.commit(r as usize, stats);
        self.fold.globals = (g.global_primal, g.global_dual);
        self.fold.next_fold = r + 1;
        self.sim.record(TraceKind::Fold { round: r });
        let fold_ns = self.obs.end(self.probes.collective_fold, span);
        self.obs.inc(self.probes.rounds, 1);
        self.record_commit(r, stats, fold_ns);
        self.foldwait_dirty = true;

        if stop {
            self.stopped = true;
            self.sim.record(TraceKind::Stop { rounds: r + 1 });
        }
    }

    /// Timeline + series bookkeeping for a committed fold. The fold runs
    /// in the omniscient oracle (no owning node), so its timeline events
    /// land on a synthetic track one past the last node id.
    fn record_commit(&mut self, r: u64, stats: IterStats, fold_ns: u64) {
        let oracle = self.nodes.len();
        if self.timeline.enabled() {
            let now = self.sim.now();
            self.timeline
                .phase(now, oracle, r, ObsPhase::CollectiveFold, fold_ns);
            self.timeline.commit(now, oracle, r);
        }
        if self.series.enabled() {
            let view = self.ctrl.view();
            let row = RoundRow {
                round: r,
                at: self.sim.now(),
                stats,
                live_nodes: view.live_count() as u64,
                live_edges: view.live_edge_count() as u64,
                phase_ns: self.timeline.phase_ns(r),
            };
            self.series.push(row);
        }
    }

    fn finish(mut self) -> NetReport {
        let n = self.nodes.len();
        let live = (0..n).map(|i| self.ctrl.view().node_live(i)).collect();
        let trace = self.sim.take_trace();
        self.obs.set_gauge(self.probes.iterations, self.fold.next_fold as f64);
        self.obs.set_gauge(self.probes.converged,
                           if self.fold.tracker.converged { 1.0 } else { 0.0 });
        let vt = self.obs.gauge("fadmm_virtual_time");
        self.obs.set_gauge(vt, self.sim.now() as f64);
        self.obs.absorb_net(&self.sim.counters);
        self.obs.absorb_trace(trace.len(), self.sim.counters.trace_dropped);
        let timeline = self.timeline.drain();
        let timeline_dropped = self.timeline.dropped();
        let series = self.series.drain();
        let series_dropped = self.series.dropped();
        self.obs.absorb_timeline(timeline.len(), timeline_dropped,
                                 series.len(), series_dropped);
        crate::obs::global_merge(&self.obs);
        if crate::obs::global_timeline_enabled() {
            crate::obs::global_timeline_merge(timeline.clone());
        }
        if crate::obs::global_series_enabled() {
            crate::obs::global_series_merge(series.clone(), series_dropped);
        }
        NetReport {
            iterations: self.fold.next_fold as usize,
            converged: self.fold.tracker.converged,
            recorder: self.fold.tracker.take_recorder(),
            thetas: self.fold.latest_committed,
            virtual_time: self.sim.now(),
            counters: self.sim.counters,
            trace,
            timeline,
            timeline_dropped,
            series,
            series_dropped,
            live,
            obs: self.obs,
        }
    }
}

// ---------------------------------------------------------------------------
// Phase bodies. Free functions over disjoint runner fields; the per-node
// arithmetic is the shared kernel ([`NodeKernel`]), so the zero-fault
// bit-parity with `Engine::step` is shared code, not a maintained
// transcription. This file supplies only the cache-backed [`SlotView`]
// (stamp resolution + staleness accounting) and the message flow.

/// Check readiness of every live slot of node `i` for a phase. Forced
/// progress still requires a non-empty cache per live slot (guaranteed
/// after the reliable handshake has arrived).
fn slots_ready<S: LocalSolver>(node: &NodeRt<S>, i: NodeId, view: &LiveView,
                               theta_ideal: u64, eta_ideal: Option<u64>,
                               stale: u64, force: bool) -> bool {
    let deg = view.graph().degree(i);
    for slot in 0..deg {
        if !view.slot_live(i, slot) {
            continue;
        }
        let c = &node.caches[slot];
        if force {
            if c.theta.is_empty() || (eta_ideal.is_some() && c.eta.is_empty()) {
                return false;
            }
        } else if !c.theta_ready(theta_ideal, stale)
            || eta_ideal.is_some_and(|ei| !c.eta_ready(ei, stale))
        {
            return false;
        }
    }
    true
}

/// The async runtime's [`SlotView`]: stamp-indexed bounded-staleness
/// cache reads with the shared staleness accounting
/// ([`NetSim::note_stale_read`]) run inside each resolve, so counters and
/// traces keep their pre-refactor order.
struct CacheSlots<'a> {
    caches: &'a mut [SlotCache],
    view: &'a LiveView,
    sim: &'a mut NetSim,
    node: NodeId,
    nbrs: &'a [NodeId],
    theta_ideal: u64,
    eta_ideal: u64,
    stale: u64,
}

impl SlotView for CacheSlots<'_> {
    fn live(&self, slot: usize) -> bool {
        self.view.slot_live(self.node, slot)
    }

    fn theta(&mut self, slot: usize) -> (&[f64], u64) {
        let used = self.caches[slot].resolve_theta(self.theta_ideal);
        self.sim.note_stale_read(self.node, self.nbrs[slot], self.theta_ideal,
                                 used, self.stale);
        (self.caches[slot].theta_at(used), self.theta_ideal.saturating_sub(used))
    }

    fn theta_again(&mut self, slot: usize) -> &[f64] {
        let (_, th) = self.caches[slot].read_theta(self.theta_ideal);
        th
    }

    fn eta_in(&mut self, slot: usize) -> f64 {
        let (used, eta) = self.caches[slot].read_eta(self.eta_ideal);
        self.sim.note_stale_read(self.node, self.nbrs[slot], self.eta_ideal,
                                 used, self.stale);
        eta
    }
}

/// Phase A: the local solve on (ideally) epoch-`t` neighbour parameters.
fn phase_a<S: LocalSolver>(node: &mut NodeRt<S>, i: NodeId, view: &LiveView,
                           scratch: &mut KernelScratch, sim: &mut NetSim,
                           tl: &mut Timeline, cfg: &NetConfig, force: bool)
                           -> bool {
    let t = node.t;
    if !slots_ready(node, i, view, t, None, cfg.max_staleness, force) {
        return false;
    }
    let graph = view.graph();
    let deg = graph.degree(i);
    {
        let NodeRt { solver, kernel, theta, theta_next, caches, .. } = node;
        let mut slots = CacheSlots {
            caches,
            view,
            sim: &mut *sim,
            node: i,
            nbrs: graph.neighbors(i),
            theta_ideal: t,
            eta_ideal: t,
            stale: cfg.max_staleness,
        };
        kernel.solve_into(solver, theta, deg, &mut slots, scratch, theta_next);
    }
    std::mem::swap(&mut node.theta, &mut node.theta_next);

    // broadcast θ^{t+1}
    for (slot, &j) in graph.neighbors(i).iter().enumerate() {
        if !view.slot_live(i, slot) {
            continue;
        }
        send_traced(sim, tl, i, j,
                    Payload::Theta { stamp: t + 1, theta: node.theta.clone() },
                    false);
    }
    true
}

/// Phase B: λ update, residuals, objectives — the round-`t` reduce. The
/// λ staleness policies (lag damping, skip-on-fallback) are the kernel's
/// [`DualPolicy`], selected by [`NetConfig::dual_policy`].
fn phase_b<S: LocalSolver>(node: &mut NodeRt<S>, i: NodeId, view: &LiveView,
                           scratch: &mut KernelScratch, sim: &mut NetSim,
                           cfg: &NetConfig, force: bool) -> Option<Contribution> {
    let t = node.t;
    if !slots_ready(node, i, view, t + 1, Some(t), cfg.max_staleness, force) {
        return None;
    }
    let graph = view.graph();
    let deg = graph.degree(i);
    {
        let NodeRt { solver, kernel, theta, caches, .. } = node;
        let mut slots = CacheSlots {
            caches,
            view,
            sim: &mut *sim,
            node: i,
            nbrs: graph.neighbors(i),
            theta_ideal: t + 1,
            eta_ideal: t,
            stale: cfg.max_staleness,
        };
        kernel.reduce(solver, theta, deg, &mut slots, cfg.dual_policy(), scratch);
    }

    Some(Contribution {
        f_self: node.kernel.f_self,
        primal: node.kernel.primal,
        dual: node.kernel.dual,
        etas: node.kernel.etas.clone(),
        theta: node.theta.clone(),
    })
}

/// Phase C: penalty-scheme update, η broadcast, topology observation.
fn phase_c<S: LocalSolver>(node: &mut NodeRt<S>, i: NodeId,
                           ctrl: &mut TopologyController, sim: &mut NetSim,
                           tl: &mut Timeline, cfg: &NetConfig,
                           globals: (f64, f64), mask_scratch: &mut Vec<bool>)
                           -> Vec<(NodeId, NodeId)> {
    let t = node.t;
    let deg = ctrl.view().graph().degree(i);
    mask_scratch.clear();
    let mut all_live = true;
    for slot in 0..deg {
        let l = ctrl.view().slot_live(i, slot);
        all_live &= l;
        mask_scratch.push(l);
    }
    // parity-critical: pass None when fully live, so the synchronous
    // engines and the zero-fault async run construct identical
    // observations
    let live = if all_live { None } else { Some(&mask_scratch[..]) };
    node.kernel.observe(t as usize, globals, live);

    // broadcast η^{t+1} (one scalar per neighbour — the directed penalty
    // the receiver needs for its symmetrized dual step)
    for (slot, &j) in ctrl.view().graph().neighbors(i).iter().enumerate() {
        if !ctrl.view().slot_live(i, slot) {
            continue;
        }
        send_traced(sim, tl, i, j,
                    Payload::Eta { stamp: t + 1, eta: node.kernel.etas[slot] },
                    false);
    }

    ctrl.observe_etas(i, &node.kernel.etas, sim)
}
