//! JSON round-trip for [`FaultPlan`] — recorded fault scenarios as data.
//!
//! The scenario matrices in `experiments` are code-defined; this module
//! lets `repro net --plan foo.json` and `repro cluster --plan foo.json`
//! replay *arbitrary* recorded loss/latency/partition/churn scenarios
//! (the ROADMAP open item). The format mirrors the [`FaultPlan`] fields
//! one-to-one, every field optional with zero-fault defaults:
//!
//! ```json
//! {
//!   "link": { "base": 2, "jitter": 4, "loss": 0.10, "dup": 0.02 },
//!   "partitions": [ { "start": 50, "end": 250, "group": [0, 1, 2] } ],
//!   "churn": [ { "kind": "join",  "at": 200, "node": 8 },
//!              { "kind": "leave", "at": 600, "node": 2 } ],
//!   "initially_dormant": [8]
//! }
//! ```
//!
//! For `repro net` the ids are node ids; for `repro cluster` they are
//! *machine* ids (the cluster transport's endpoints). [`plan_to_json`] is
//! the exact inverse of [`plan_from_json`], asserted by the round-trip
//! test below, so plans can be programmatically generated, saved and
//! replayed. An example plan ships at `examples/net_plan_loss_partition.json`.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{arr, num, obj, s, Json};

use super::sim::{ChurnEvent, FaultPlan, LinkModel, Partition};

/// Load a plan from a JSON file.
pub fn load_plan(path: &Path) -> Result<FaultPlan> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(format!("read plan {}", path.display()), e))?;
    plan_from_json(&Json::parse(&text)?)
}

fn req_u64(j: &Json, key: &str, ctx: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_f64)
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as u64)
        .ok_or_else(|| Error::Config(format!("plan: {ctx} needs integer '{key}'")))
}

fn req_usize(j: &Json, key: &str, ctx: &str) -> Result<usize> {
    Ok(req_u64(j, key, ctx)? as usize)
}

/// Parse a plan from its JSON form (all fields optional).
pub fn plan_from_json(j: &Json) -> Result<FaultPlan> {
    let mut plan = FaultPlan::none();

    if let Some(link) = j.get("link") {
        let f = |key: &str, default: f64| -> Result<f64> {
            match link.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| Error::Config(format!("plan: link.{key} not a number"))),
            }
        };
        let int = |key: &str| -> Result<u64> {
            match link.get(key) {
                None => Ok(0),
                Some(v) => v
                    .as_f64()
                    .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                    .map(|x| x as u64)
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "plan: link.{key} must be a non-negative integer"
                        ))
                    }),
            }
        };
        let loss = f("loss", 0.0)?;
        let dup = f("dup", 0.0)?;
        if !(0.0..=1.0).contains(&loss) || !(0.0..=1.0).contains(&dup) {
            return Err(Error::Config("plan: loss/dup must lie in [0, 1]".into()));
        }
        plan.link = LinkModel { base: int("base")?, jitter: int("jitter")?, loss, dup };
    }

    if let Some(parts) = j.get("partitions") {
        let parts = parts
            .as_arr()
            .ok_or_else(|| Error::Config("plan: 'partitions' must be an array".into()))?;
        for p in parts {
            let group = p
                .get("group")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Config("plan: partition needs 'group' array".into()))?
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| Error::Config("plan: group ids must be integers".into()))
                })
                .collect::<Result<Vec<usize>>>()?;
            let start = req_u64(p, "start", "partition")?;
            let end = req_u64(p, "end", "partition")?;
            if end < start {
                return Err(Error::Config("plan: partition end < start".into()));
            }
            plan.partitions.push(Partition { start, end, group });
        }
    }

    if let Some(churn) = j.get("churn") {
        let churn = churn
            .as_arr()
            .ok_or_else(|| Error::Config("plan: 'churn' must be an array".into()))?;
        for c in churn {
            let at = req_u64(c, "at", "churn event")?;
            let node = req_usize(c, "node", "churn event")?;
            let kind = c
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Config("plan: churn event needs 'kind'".into()))?;
            plan.churn.push(match kind {
                "join" => ChurnEvent::Join { at, node },
                "leave" => ChurnEvent::Leave { at, node },
                other => {
                    return Err(Error::Config(format!(
                        "plan: unknown churn kind '{other}' (join|leave)"
                    )))
                }
            });
        }
    }

    if let Some(dormant) = j.get("initially_dormant") {
        let dormant = dormant.as_arr().ok_or_else(|| {
            Error::Config("plan: 'initially_dormant' must be an array".into())
        })?;
        for v in dormant {
            plan.initially_dormant.push(v.as_usize().ok_or_else(|| {
                Error::Config("plan: dormant ids must be integers".into())
            })?);
        }
    }

    Ok(plan)
}

/// Serialize a plan (exact inverse of [`plan_from_json`]).
pub fn plan_to_json(plan: &FaultPlan) -> Json {
    let link = obj(vec![
        ("base", num(plan.link.base as f64)),
        ("jitter", num(plan.link.jitter as f64)),
        ("loss", num(plan.link.loss)),
        ("dup", num(plan.link.dup)),
    ]);
    let partitions = arr(plan
        .partitions
        .iter()
        .map(|p| {
            obj(vec![
                ("start", num(p.start as f64)),
                ("end", num(p.end as f64)),
                ("group", arr(p.group.iter().map(|&g| num(g as f64)).collect())),
            ])
        })
        .collect());
    let churn = arr(plan
        .churn
        .iter()
        .map(|c| match *c {
            ChurnEvent::Join { at, node } => obj(vec![
                ("kind", s("join")),
                ("at", num(at as f64)),
                ("node", num(node as f64)),
            ]),
            ChurnEvent::Leave { at, node } => obj(vec![
                ("kind", s("leave")),
                ("at", num(at as f64)),
                ("node", num(node as f64)),
            ]),
        })
        .collect());
    let dormant = arr(plan
        .initially_dormant
        .iter()
        .map(|&i| num(i as f64))
        .collect());
    obj(vec![
        ("link", link),
        ("partitions", partitions),
        ("churn", churn),
        ("initially_dormant", dormant),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_plan() -> FaultPlan {
        FaultPlan {
            link: LinkModel { base: 2, jitter: 4, loss: 0.125, dup: 0.0625 },
            partitions: vec![Partition { start: 50, end: 250, group: vec![0, 1, 2] }],
            churn: vec![
                ChurnEvent::Join { at: 200, node: 8 },
                ChurnEvent::Leave { at: 600, node: 2 },
            ],
            initially_dormant: vec![8],
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let plan = sample_plan();
        let j = plan_to_json(&plan);
        let back = plan_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.link.base, plan.link.base);
        assert_eq!(back.link.jitter, plan.link.jitter);
        assert_eq!(back.link.loss, plan.link.loss, "dyadic loss survives exactly");
        assert_eq!(back.link.dup, plan.link.dup);
        assert_eq!(back.partitions.len(), 1);
        assert_eq!(back.partitions[0].start, 50);
        assert_eq!(back.partitions[0].end, 250);
        assert_eq!(back.partitions[0].group, vec![0, 1, 2]);
        assert_eq!(back.churn, plan.churn);
        assert_eq!(back.initially_dormant, vec![8]);
        // and the re-serialization is byte-identical
        assert_eq!(plan_to_json(&back).to_string(), j.to_string());
    }

    #[test]
    fn empty_object_is_the_zero_fault_plan() {
        let plan = plan_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(plan.link.loss, 0.0);
        assert_eq!(plan.link.base, 0);
        assert!(plan.partitions.is_empty());
        assert!(plan.churn.is_empty());
        assert!(plan.initially_dormant.is_empty());
    }

    #[test]
    fn rejects_malformed_plans() {
        for bad in [
            r#"{"link": {"loss": 1.5}}"#,
            r#"{"link": {"base": -2}}"#,
            r#"{"link": {"jitter": 2.7}}"#,
            r#"{"partitions": [{"start": 9, "end": 3, "group": [0]}]}"#,
            r#"{"partitions": [{"start": 0, "end": 3}]}"#,
            r#"{"churn": [{"kind": "explode", "at": 1, "node": 0}]}"#,
            r#"{"churn": [{"kind": "join", "node": 0}]}"#,
            r#"{"initially_dormant": [1.5]}"#,
        ] {
            assert!(plan_from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn example_plan_file_parses() {
        // the shipped demo plan must stay loadable
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../examples/net_plan_loss_partition.json");
        let plan = load_plan(&path).unwrap();
        assert!(plan.link.loss > 0.0);
        assert!(!plan.partitions.is_empty());
    }
}
