//! The cluster transport seam: one trait over "send a [`Payload`], pop
//! the next [`Event`], keep time", with three implementations.
//!
//! | transport | clock | determinism | fault model | role |
//! |-----------|-------|-------------|-------------|------|
//! | [`NetSim`] (`net::sim`) | virtual ticks | bit-exact replay per seed | scripted loss/jitter/dup/partition/churn | oracle: parity suites pin the protocol against it |
//! | [`ChannelTransport`] (in-process) | real (`Instant`, ms) | real interleavings, convergence-level checks only | none intrinsic; the harness injects [`Event::Leave`] | one OS thread per machine, `mpsc` mesh |
//! | `StdioTransport` (`cluster::proc`) | real (`Instant`, ms) | real interleavings + real process death | SIGKILL by the driver; leave/join ctrl lines | one OS *process* per machine, line-delimited JSON via `fadmm-node` |
//!
//! The protocol code ([`crate::cluster`]) is generic over [`Transport`]
//! and cannot tell which one it runs on: the simulator path is pinned
//! bit-identical to the pre-trait code by the existing parity suites,
//! and the real transports assert convergence-within-tolerance plus
//! identical iteration counts at zero faults.
//!
//! Real transports have no virtual clock, so [`Transport::advance_to`]
//! is a no-op and [`Transport::now`] reads wall time in milliseconds —
//! tick-valued config timeouts (silence, collective patience, gossip
//! spacing) become millisecond timeouts. A consumer that wants
//! iteration-count parity at zero faults therefore configures timeouts
//! generously enough that they never fire spuriously under scheduler
//! noise.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

use crate::graph::NodeId;
use crate::metrics::NetCounters;
use crate::obs::{FlightRecorder, Timeline, TraceCtx, DEFAULT_TRACE_CAPACITY};

use super::sim::{Event, NetSim, Payload, Ticks, TraceEvent, TraceKind};

/// The machine-level send/deliver/clock surface the cluster runtime
/// needs. Extracted verbatim from [`NetSim`]'s public API so the
/// simulator implementation is pure forwarding.
pub trait Transport {
    /// Current time: virtual ticks (sim) or elapsed wall milliseconds
    /// (real transports).
    fn now(&self) -> Ticks;

    /// Send a protocol message. The sim applies its fault plan unless
    /// `reliable`; real transports deliver best-effort (a dead peer
    /// just never reads it) and ignore the flag. Returns the frame's
    /// minted [`TraceCtx`] — one integer increment per send, on every
    /// transport, whether or not a timeline records it (so the wire is
    /// identical with tracing on and off).
    fn send(&mut self, src: NodeId, dst: NodeId, payload: Payload, reliable: bool)
        -> TraceCtx;

    /// Schedule a consumer timer ([`Event::Wake`] / [`Event::Timer`])
    /// at absolute time `at`.
    fn schedule(&mut self, at: Ticks, event: Event);

    /// Pop the next event without advancing the clock (sim) /
    /// block until traffic or a due timer (real). `None` means the run
    /// is over: queue exhausted (sim) or all peers hung up with no
    /// timer pending (real).
    fn pop(&mut self) -> Option<(Ticks, Event)>;

    /// Advance the virtual clock (no-op on real transports — wall time
    /// advances itself).
    fn advance_to(&mut self, at: Ticks);

    /// Append a consumer-side trace entry at the current time.
    fn record(&mut self, kind: TraceKind);

    /// Bookkeeping for a resolved stale read (see
    /// [`NetSim::note_stale_read`]).
    fn note_stale_read(&mut self, node: NodeId, nbr: NodeId, ideal: u64,
                       used: u64, stale: u64);

    /// Bookkeeping for a delivery the consumer accepted.
    fn note_delivered(&mut self, src: NodeId, dst: NodeId, payload: &Payload);

    /// Bookkeeping for a delivery whose destination was dead.
    fn note_dead_delivery(&mut self, src: NodeId, dst: NodeId, payload: &Payload);

    /// The live counter block (consumer-maintained counters increment
    /// through this).
    fn counters(&mut self) -> &mut NetCounters;

    /// Copy of the counters for reports.
    fn counters_snapshot(&self) -> NetCounters;

    /// Take the accumulated trace for the final report.
    fn take_trace(&mut self) -> Vec<TraceEvent>;
}

/// [`Transport::send`] + [`Timeline::send`] in one call with disjoint
/// borrows (the runtimes hold the transport and the timeline as sibling
/// fields, so a `&mut self` method can't do this). The clock read is
/// gated on the timeline being live: a timeline-off run performs
/// *exactly* the sends the pre-timeline code did — same wire frames,
/// same counters, no extra `now()` (which is a wall read on real
/// transports).
pub fn send_traced<T: Transport>(
    net: &mut T,
    tl: &mut Timeline,
    src: NodeId,
    dst: NodeId,
    payload: Payload,
    reliable: bool,
) {
    let what = payload.kind_name();
    let ctx = net.send(src, dst, payload, reliable);
    if tl.enabled() {
        tl.send(net.now(), ctx, dst, what);
    }
}

/// The simulator *is* the first transport: pure forwarding, so the
/// pre-trait behaviour is bit-identical (pinned by `cluster::tests`).
impl Transport for NetSim {
    fn now(&self) -> Ticks {
        NetSim::now(self)
    }

    fn send(&mut self, src: NodeId, dst: NodeId, payload: Payload, reliable: bool)
        -> TraceCtx
    {
        NetSim::send(self, src, dst, payload, reliable)
    }

    fn schedule(&mut self, at: Ticks, event: Event) {
        NetSim::schedule(self, at, event);
    }

    fn pop(&mut self) -> Option<(Ticks, Event)> {
        NetSim::pop(self)
    }

    fn advance_to(&mut self, at: Ticks) {
        NetSim::advance_to(self, at);
    }

    fn record(&mut self, kind: TraceKind) {
        NetSim::record(self, kind);
    }

    fn note_stale_read(&mut self, node: NodeId, nbr: NodeId, ideal: u64,
                       used: u64, stale: u64) {
        NetSim::note_stale_read(self, node, nbr, ideal, used, stale);
    }

    fn note_delivered(&mut self, src: NodeId, dst: NodeId, payload: &Payload) {
        NetSim::note_delivered(self, src, dst, payload);
    }

    fn note_dead_delivery(&mut self, src: NodeId, dst: NodeId, payload: &Payload) {
        NetSim::note_dead_delivery(self, src, dst, payload);
    }

    fn counters(&mut self) -> &mut NetCounters {
        &mut self.counters
    }

    fn counters_snapshot(&self) -> NetCounters {
        self.counters
    }

    fn take_trace(&mut self) -> Vec<TraceEvent> {
        NetSim::take_trace(self)
    }
}

/// In-process real transport: every machine is an OS thread, messages
/// travel over an all-to-all [`std::sync::mpsc`] mesh, and the clock is
/// shared wall time in milliseconds. There is no virtual event queue —
/// [`Transport::pop`] blocks on the channel with a timeout derived from
/// the earliest armed timer, so real scheduler interleavings (the thing
/// the simulator cannot produce) drive the protocol.
pub struct ChannelTransport {
    id: NodeId,
    epoch: Instant,
    rx: Receiver<Event>,
    peers: Vec<Sender<Event>>,
    /// armed consumer timers: (due, seq, event); linear min-scan (the
    /// runner keeps at most a handful armed per machine)
    timers: Vec<(Ticks, u64, Event)>,
    seq: u64,
    /// frames minted so far (the next [`TraceCtx::seq`]); disjoint from
    /// the timer tie-break `seq`
    frames: u64,
    tracing: bool,
    trace: FlightRecorder<TraceEvent>,
    pub counters: NetCounters,
}

/// Build an all-to-all in-process mesh for `machines` endpoints.
/// Returns one transport per machine plus the raw senders, which a
/// harness can use to inject events from outside (e.g. an
/// [`Event::Leave`] broadcast standing in for a machine kill).
///
/// Each endpoint's *own* slot in its peer list is a pre-disconnected
/// sender: the protocol never self-sends, and holding one's own sender
/// would keep the receive side alive forever — the disconnect path
/// (every other endpoint and the harness senders gone) is what lets a
/// lone survivor drain its timers and terminate.
pub fn channel_mesh(machines: usize, tracing: bool)
    -> (Vec<ChannelTransport>, Vec<Sender<Event>>)
{
    let epoch = Instant::now();
    let mut txs = Vec::with_capacity(machines);
    let mut rxs = Vec::with_capacity(machines);
    for _ in 0..machines {
        let (tx, rx) = std::sync::mpsc::channel();
        txs.push(tx);
        rxs.push(rx);
    }
    let transports = rxs
        .into_iter()
        .enumerate()
        .map(|(id, rx)| {
            let mut peers = txs.clone();
            peers[id] = {
                let (tx, _dropped_rx) = std::sync::mpsc::channel();
                tx
            };
            ChannelTransport {
                id,
                epoch,
                rx,
                peers,
                timers: Vec::new(),
                seq: 0,
                frames: 0,
                tracing,
                trace: FlightRecorder::new(if tracing { DEFAULT_TRACE_CAPACITY } else { 0 }),
                counters: NetCounters::default(),
            }
        })
        .collect();
    (transports, txs)
}

impl ChannelTransport {
    /// This endpoint's machine id.
    pub fn id(&self) -> NodeId {
        self.id
    }

    /// Resize the flight recorder (setup only — discards anything
    /// already recorded).
    pub fn set_trace_capacity(&mut self, cap: usize) {
        self.trace = FlightRecorder::new(cap);
    }

    fn trace_push(&mut self, ev: TraceEvent) {
        self.trace.push(ev);
        self.counters.trace_dropped = self.trace.dropped();
    }

    /// Index of the earliest armed timer by (due, seq).
    fn next_timer(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, t) in self.timers.iter().enumerate() {
            match best {
                None => best = Some(i),
                Some(b) => {
                    if (t.0, t.1) < (self.timers[b].0, self.timers[b].1) {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    /// All peers hung up: sleep out the earliest timer (so a detached
    /// survivor can still drive local-fold fallbacks to completion)
    /// instead of firing it early.
    fn pop_after_disconnect(&mut self) -> Option<(Ticks, Event)> {
        let i = self.next_timer()?;
        let due = self.timers[i].0;
        let now = self.now();
        if due > now {
            std::thread::sleep(Duration::from_millis(due - now));
        }
        let (_, _, event) = self.timers.remove(i);
        Some((self.now(), event))
    }
}

impl Transport for ChannelTransport {
    fn now(&self) -> Ticks {
        self.epoch.elapsed().as_millis() as Ticks
    }

    fn send(&mut self, src: NodeId, dst: NodeId, payload: Payload, _reliable: bool)
        -> TraceCtx
    {
        self.counters.sent += 1;
        let stamp = payload.stamp();
        let what = payload.kind_name();
        let ctx = TraceCtx { round: stamp, machine: src, seq: self.frames };
        self.frames += 1;
        if self.tracing {
            self.trace_push(TraceEvent { at: self.now(), kind: TraceKind::Send { src, dst, what, stamp } });
        }
        let ev = Event::Deliver { src, dst, payload, dup: false, ctx };
        if self.peers[dst].send(ev).is_err() {
            // peer thread exited — the real-world analogue of a dead
            // destination
            self.counters.dropped_dead += 1;
            if self.tracing {
                self.trace_push(TraceEvent { at: self.now(), kind: TraceKind::DropDead { src, dst, stamp } });
            }
        }
        ctx
    }

    fn schedule(&mut self, at: Ticks, event: Event) {
        let seq = self.seq;
        self.seq += 1;
        self.timers.push((at.max(self.now()), seq, event));
    }

    fn pop(&mut self) -> Option<(Ticks, Event)> {
        loop {
            // arrived traffic first: a due timer must not outrace
            // messages that are already in the queue, or generous
            // timeouts would still fire spuriously under load
            match self.rx.try_recv() {
                Ok(ev) => return Some((self.now(), ev)),
                Err(TryRecvError::Disconnected) => return self.pop_after_disconnect(),
                Err(TryRecvError::Empty) => {}
            }
            match self.next_timer() {
                Some(i) if self.timers[i].0 <= self.now() => {
                    let (_, _, event) = self.timers.remove(i);
                    return Some((self.now(), event));
                }
                Some(i) => {
                    // saturating: the clock may tick past the deadline
                    // between the guard above and this read
                    let wait = self.timers[i].0.saturating_sub(self.now());
                    match self.rx.recv_timeout(Duration::from_millis(wait)) {
                        Ok(ev) => return Some((self.now(), ev)),
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => {
                            return self.pop_after_disconnect()
                        }
                    }
                }
                None => match self.rx.recv() {
                    Ok(ev) => return Some((self.now(), ev)),
                    Err(_) => return None,
                },
            }
        }
    }

    fn advance_to(&mut self, _at: Ticks) {}

    fn record(&mut self, kind: TraceKind) {
        if self.tracing {
            self.trace_push(TraceEvent { at: self.now(), kind });
        }
    }

    fn note_stale_read(&mut self, node: NodeId, nbr: NodeId, ideal: u64,
                       used: u64, stale: u64) {
        if used < ideal {
            self.counters.stale_reads += 1;
            if used + stale < ideal {
                self.counters.fallback_reads += 1;
                self.record(TraceKind::Fallback { node, nbr, ideal, used });
            }
        }
    }

    fn note_delivered(&mut self, src: NodeId, dst: NodeId, payload: &Payload) {
        self.counters.delivered += 1;
        if self.tracing {
            let kind = TraceKind::Deliver {
                src,
                dst,
                what: payload.kind_name(),
                stamp: payload.stamp(),
            };
            self.trace_push(TraceEvent { at: self.now(), kind });
        }
    }

    fn note_dead_delivery(&mut self, src: NodeId, dst: NodeId, payload: &Payload) {
        self.counters.dropped_dead += 1;
        self.record(TraceKind::DropDead { src, dst, stamp: payload.stamp() });
    }

    fn counters(&mut self) -> &mut NetCounters {
        &mut self.counters
    }

    fn counters_snapshot(&self) -> NetCounters {
        self.counters
    }

    fn take_trace(&mut self) -> Vec<TraceEvent> {
        self.counters.trace_dropped = self.trace.dropped();
        self.trace.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // exercise the sim exclusively through the trait, as the generic
    // runner does
    fn drive<T: Transport>(t: &mut T) -> NetCounters {
        t.send(0, 1, Payload::Eta { stamp: 3, eta: 0.5 }, false);
        t.schedule(7, Event::Wake { node: 0, epoch: 0 });
        let (at, ev) = t.pop().unwrap();
        t.advance_to(at);
        match ev {
            Event::Deliver { src: 0, dst: 1, payload, dup: false, ctx } => {
                assert_eq!(ctx.machine, 0, "ctx is minted by the sender");
                t.note_delivered(0, 1, &payload);
            }
            other => panic!("unexpected {other:?}"),
        }
        let (at, ev) = t.pop().unwrap();
        assert_eq!(ev, Event::Wake { node: 0, epoch: 0 });
        t.advance_to(at);
        t.counters_snapshot()
    }

    #[test]
    fn sim_forwards_through_the_trait() {
        use super::super::sim::FaultPlan;
        let mut sim = NetSim::new(1, FaultPlan::none(), true);
        let c = drive(&mut sim);
        assert_eq!((c.sent, c.delivered), (1, 1));
        assert_eq!(NetSim::now(&sim), 7, "trait advance moved the virtual clock");
        assert!(!sim.take_trace().is_empty());
    }

    #[test]
    fn channel_mesh_routes_between_endpoints() {
        let (mut mesh, _txs) = channel_mesh(2, true);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        assert_eq!((a.id(), b.id()), (0, 1));
        a.send(0, 1, Payload::Eta { stamp: 9, eta: 1.5 }, false);
        let (_, ev) = b.pop().unwrap();
        match ev {
            Event::Deliver { src: 0, dst: 1, payload, dup: false, ctx } => {
                assert_eq!(payload, Payload::Eta { stamp: 9, eta: 1.5 });
                assert_eq!(
                    ctx,
                    TraceCtx { round: 9, machine: 0, seq: 0 },
                    "first frame from machine 0 carries (round=stamp, seq=0)"
                );
                b.note_delivered(0, 1, &payload);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.counters_snapshot().delivered, 1);
        assert_eq!(a.counters_snapshot().sent, 1);
    }

    #[test]
    fn channel_timers_fire_in_due_order() {
        let (mut mesh, txs) = channel_mesh(1, false);
        let mut t = mesh.pop().unwrap();
        drop(txs); // nothing will ever send — pure timer path
        let now = t.now();
        t.schedule(now + 20, Event::Wake { node: 0, epoch: 1 });
        t.schedule(now + 5, Event::Wake { node: 0, epoch: 0 });
        let (_, first) = t.pop().unwrap();
        let (_, second) = t.pop().unwrap();
        assert_eq!(first, Event::Wake { node: 0, epoch: 0 });
        assert_eq!(second, Event::Wake { node: 0, epoch: 1 });
        assert!(t.pop().is_none(), "no peers, no timers: run over");
    }
}
