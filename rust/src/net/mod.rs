//! Simulated-network runtime: asynchronous, fault-tolerant ADMM over a
//! deterministic discrete-event network with a live dynamic topology.
//!
//! The synchronous runtimes ([`crate::consensus::Engine`] and the sharded
//! [`crate::coordinator`]) assume a fixed graph, lock-step phase barriers
//! and perfectly reliable neighbour reads. This module removes all three
//! assumptions while keeping the zero-fault case **bit-for-bit identical**
//! to the sequential engine, so every fault scenario has a trusted oracle
//! to diff against:
//!
//! * [`sim`] — a seeded discrete-event simulator: virtual clock, per-link
//!   latency distributions, Bernoulli loss and duplication, scripted
//!   transient partitions and join/leave churn, and a replayable event
//!   trace (same seed ⇒ identical trace, byte for byte).
//! * [`AsyncRunner`] — ADMM over that transport with *bounded-staleness*
//!   neighbour caches instead of barriers: a round-`t` read ideally
//!   resolves stamp `t`, may lag up to `max_staleness` rounds, and after
//!   `silence_timeout` virtual ticks of silence falls back to the best
//!   cached η̄/θ̄ (forced progress; counted and traced). Reuses
//!   [`crate::consensus::LocalSolver::solve_into`] and the existing
//!   penalty schemes through [`crate::penalty::NodeObservation`].
//! * [`TopologyController`] — applies scripted churn *and* the NAP
//!   scheme's effective-topology decisions (persistently weak edges mask
//!   off, with hysteresis) to a live [`crate::graph::LiveView`], keeping
//!   η̄ normalization and isolated-node semantics correct as edges appear
//!   and disappear.
//!
//! ## Staleness / fallback semantics (summary)
//!
//! Let `s = max_staleness`. Node `i` may *start* phase A of round `t`
//! once every live neighbour has a cached θ stamped `≥ t − s`, and phase
//! B once θ `≥ t+1 − s` and η `≥ t − s`; reads then resolve to the
//! largest stamp `≤` the ideal. A silent neighbour (nothing fresh for
//! `silence_timeout` ticks) stops gating progress: the node proceeds on
//! the stale cache, which is always populated because the join handshake
//! is delivered reliably. `s = 0` with no faults reproduces the exact
//! synchronous schedule — the parity tests in `net::tests` assert
//! bit-identical θ/λ/η trajectories and recorder curves against
//! [`crate::consensus::Engine`] on Ring and Star for all seven schemes.
//!
//! **Stability boundary.** The staleness budget is a wait-relaxation, so
//! nodes free-run at the budget: under load, most reads sit exactly `s`
//! rounds behind. Each stale λ update breaks the per-edge cancellation
//! that keeps Σ_i λ_i = 0, and that error feeds back through the next
//! solve; on the quadratic consensus workloads (η⁰ = 10), `s ≤ 1`
//! converges to machine precision under 30% loss while `s ≥ 2` diverges
//! exponentially — the classic delay × step-size tradeoff of
//! asynchronous ADMM. Keep `max_staleness ≤ 1` unless the penalty is
//! small against the local curvature; the `net_scenarios` sweep keeps a
//! `stale3` cell as the measured counterexample. A side effect of the
//! same mechanism: a bounded amount of stale reading permanently biases
//! the async fixed point (consensus still holds — all nodes agree — but
//! the agreed point shifts slightly from the synchronous optimum).
//!
//! **Stale-dual policies.** Two complementary one-line kernel policies
//! ([`crate::kernel::DualPolicy`]) blunt the stale-λ feedback without
//! touching the exact-read arithmetic:
//!
//! * [`NetConfig::lag_damping`] *shrinks* every stale dual step by
//!   `1/(1+lag)` — graceful degradation proportional to how stale the
//!   read was, at the cost of slowing the dual on *every* lagged edge,
//!   including mildly stale ones that were still informative;
//! * [`NetConfig::skip_lambda_on_fallback`] *drops* the dual step only
//!   for forced fallback reads (lag past the `max_staleness` budget),
//!   where the generation mismatch is unbounded and the step is mostly
//!   noise — within-budget stale steps keep their full magnitude, so
//!   convergence speed is preserved when the budget holds, but a long
//!   outage freezes λ on the silent edge entirely (the bias parks
//!   instead of drifting).
//!
//! Both are bit-transparent when no read lags; together they skip beyond
//! the budget and damp within it. The `stale3` / `stale3_damped` /
//! `stale3_skip` scenario cells measure the raw / shrink / drop variants
//! of the same over-budget regime side by side.
//!
//! ## NAP → topology mapping (summary)
//!
//! The paper's NAP budgets starve adaptation on edges whose τ stream
//! stays uninformative; those edges' penalties pin at η⁰ while active
//! edges grow theirs, so their *relative influence* η̄_ij / mean(η̄)
//! collapses — the "dotted" edges of Fig. 1c. With
//! [`ActivityConfig`] enabled, the controller makes that physical: a
//! persistently low-influence edge is deactivated (messages stop, degrees
//! shrink), and recovers via hysteresis if its influence returns. Churn
//! and partitions exercise the same mask machinery, so "NAP-induced
//! topology" and "failure-induced topology" are one code path.

//! ## Relation to the cluster runtime
//!
//! This module's global fold is an *omniscient-simulator oracle*: the
//! runner folds every node's contribution in id order, which no real
//! deployment could do. [`crate::cluster`] replaces it with physical
//! collectives (spanning-tree reduce/broadcast, push-sum gossip) over a
//! machine-level instance of this same transport, and measures what that
//! realism costs; the per-node runtime here keeps the oracle fold as the
//! trusted reference. Fault scenarios for both runtimes can be recorded
//! and replayed as JSON [`FaultPlan`]s (see [`plan`]).
//!
//! ## Transports
//!
//! The machine-level surface the cluster runtime drives (send, event
//! drain, timers, delivery accounting) is the [`Transport`] trait; the
//! simulator is merely its reference implementation. The matrix:
//!
//! | transport | clock | determinism | fault model | role |
//! |---|---|---|---|---|
//! | [`NetSim`] | virtual ticks | bit-exact per seed | scripted [`FaultPlan`] | oracle + fault studies |
//! | [`ChannelTransport`] | wall (ms since start) | real thread interleavings | injected `Leave` events | in-process stress |
//! | `StdioTransport` (in [`crate::cluster::proc`]) | wall (ms since start) | real processes | `SIGKILL` mid-run | end-to-end deployment drill |
//!
//! The real transports speak the hand-rolled JSON wire format in
//! [`codec`]; the simulator clones payloads in memory and never
//! serializes.

mod async_runner;
pub mod codec;
pub mod plan;
pub mod sim;
mod topology;
pub mod transport;

pub use async_runner::{AppMetricHook, AsyncRunner, NetConfig, NetReport};
pub use codec::{payload_from_json, payload_to_json, snapshot_from_json,
                snapshot_to_json};
pub use plan::{load_plan, plan_from_json, plan_to_json};
pub use sim::{ChurnEvent, Event, FaultPlan, LinkModel, NetSim, Partition, Payload,
              Ticks, TimerKind, TraceEvent, TraceKind};
pub use topology::{ActivityConfig, TopologyController};
pub use transport::{channel_mesh, ChannelTransport, Transport};

#[cfg(test)]
mod tests;
