//! Net-runtime integration tests: zero-fault bit-parity against the
//! sequential engine, determinism of the event trace, and convergence
//! under loss, churn and partitions.

use super::*;
use crate::consensus::{Engine, EngineConfig};
// the same seeded quadratic workload the sweep and benches run
use crate::experiments::common::quad_problem as quad_nodes;
use crate::graph::{Graph, Topology};
use crate::metrics::IterStats;
use crate::penalty::SchemeKind;

fn assert_stats_bit_equal(a: &IterStats, b: &IterStats) {
    assert_eq!(a.iter, b.iter);
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "iter {}", a.iter);
    assert_eq!(a.max_primal.to_bits(), b.max_primal.to_bits(), "iter {}", a.iter);
    assert_eq!(a.max_dual.to_bits(), b.max_dual.to_bits(), "iter {}", a.iter);
    assert_eq!(a.mean_eta.to_bits(), b.mean_eta.to_bits(), "iter {}", a.iter);
    assert_eq!(a.min_eta.to_bits(), b.min_eta.to_bits(), "iter {}", a.iter);
    assert_eq!(a.max_eta.to_bits(), b.max_eta.to_bits(), "iter {}", a.iter);
}

/// Max pairwise parameter distance over a node subset.
fn spread(thetas: &[Vec<f64>], keep: &[bool]) -> f64 {
    let mut worst = 0.0f64;
    for (i, ti) in thetas.iter().enumerate() {
        if !keep[i] {
            continue;
        }
        for (j, tj) in thetas.iter().enumerate() {
            if j <= i || !keep[j] {
                continue;
            }
            let d = ti
                .iter()
                .zip(tj)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            worst = worst.max(d);
        }
    }
    worst
}

// -- satellite: zero-fault parity -------------------------------------------

#[test]
fn zero_fault_parity_bitwise_ring_and_star_all_schemes() {
    // the acceptance bar: AsyncRunner with no loss, no latency, no churn
    // and max_staleness 0 reproduces the Engine trajectory bit-for-bit —
    // final θ, iteration count, convergence flag and every recorded
    // IterStats field — for all seven schemes on Ring and Star
    for topo in [Topology::Ring, Topology::Star] {
        for scheme in SchemeKind::ALL {
            let cfg_common = (1e-4, 60usize, 11u64);
            let (tol, max_iters, seed) = cfg_common;
            let mut engine = Engine::new(
                topo.build(6).unwrap(),
                quad_nodes(6, 3, 5),
                EngineConfig { scheme, tol, max_iters, seed, ..Default::default() },
            );
            let sequential = engine.run();

            let runner = AsyncRunner::new(
                topo.build(6).unwrap(),
                quad_nodes(6, 3, 5),
                NetConfig { scheme, tol, max_iters, seed, ..Default::default() },
                FaultPlan::none(),
            );
            let asynchronous = runner.run();

            assert_eq!(sequential.iterations, asynchronous.iterations,
                       "{topo:?}/{scheme:?}");
            assert_eq!(sequential.converged, asynchronous.converged,
                       "{topo:?}/{scheme:?}");
            assert_eq!(sequential.thetas, asynchronous.thetas,
                       "{topo:?}/{scheme:?}: θ must be bit-identical");
            assert_eq!(sequential.recorder.stats.len(),
                       asynchronous.recorder.stats.len());
            for (a, b) in sequential
                .recorder
                .stats
                .iter()
                .zip(&asynchronous.recorder.stats)
            {
                assert_stats_bit_equal(a, b);
            }
            // zero faults ⇒ no virtual time passes, nothing drops, no
            // stale or forced reads
            assert_eq!(asynchronous.virtual_time, 0, "{topo:?}/{scheme:?}");
            assert_eq!(asynchronous.counters.dropped_total(), 0);
            assert_eq!(asynchronous.counters.stale_reads, 0);
            assert_eq!(asynchronous.counters.fallback_reads, 0);
        }
    }
}

#[test]
fn zero_iteration_budget_returns_theta0() {
    let engine_thetas = {
        let mut engine = Engine::new(
            Topology::Ring.build(5).unwrap(),
            quad_nodes(5, 2, 3),
            EngineConfig { max_iters: 0, ..Default::default() },
        );
        engine.run().thetas
    };
    let report = AsyncRunner::new(
        Topology::Ring.build(5).unwrap(),
        quad_nodes(5, 2, 3),
        NetConfig { max_iters: 0, ..Default::default() },
        FaultPlan::none(),
    )
    .run();
    assert_eq!(report.iterations, 0);
    assert!(!report.converged);
    assert_eq!(report.thetas, engine_thetas, "θ⁰ seeding is engine-identical");
}

#[test]
fn isolated_node_matches_engine() {
    let mut engine = Engine::new(
        Graph::new(1, &[]).unwrap(),
        quad_nodes(1, 3, 9),
        EngineConfig { max_iters: 20, tol: 0.0, ..Default::default() },
    );
    let sequential = engine.run();
    let report = AsyncRunner::new(
        Graph::new(1, &[]).unwrap(),
        quad_nodes(1, 3, 9),
        NetConfig { max_iters: 20, tol: 0.0, ..Default::default() },
        FaultPlan::none(),
    )
    .run();
    assert_eq!(report.iterations, 20);
    assert_eq!(sequential.thetas, report.thetas);
    for (a, b) in sequential.recorder.stats.iter().zip(&report.recorder.stats) {
        assert_stats_bit_equal(a, b);
    }
}

// -- satellite: determinism --------------------------------------------------

#[test]
fn same_seed_identical_trace_and_theta() {
    let run = || {
        let plan = FaultPlan {
            link: LinkModel { base: 2, jitter: 5, loss: 0.15, dup: 0.05 },
            partitions: vec![Partition { start: 40, end: 120, group: vec![0, 1, 2] }],
            churn: vec![ChurnEvent::Leave { at: 300, node: 4 }],
            initially_dormant: vec![],
        };
        AsyncRunner::new(
            Topology::Ring.build(6).unwrap(),
            quad_nodes(6, 2, 21),
            NetConfig {
                scheme: SchemeKind::Nap,
                tol: 0.0,
                max_iters: 120,
                max_staleness: 1,
                silence_timeout: 16,
                ..Default::default()
            },
            plan,
        )
        .run()
    };
    let a = run();
    let b = run();
    assert!(!a.trace.is_empty());
    assert_eq!(a.trace, b.trace, "event trace must replay identically");
    assert_eq!(a.thetas, b.thetas);
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.virtual_time, b.virtual_time);
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.recorder.objective_curve(), b.recorder.objective_curve());
}

// -- fault scenarios ---------------------------------------------------------

#[test]
fn lossy_network_still_reaches_consensus() {
    // ≥10% drop, latency jitter, bounded staleness: the acceptance
    // scenario minus churn. Primal residual must fall below tolerance.
    let plan = FaultPlan {
        link: LinkModel { base: 2, jitter: 4, loss: 0.12, dup: 0.02 },
        ..FaultPlan::none()
    };
    let report = AsyncRunner::new(
        Topology::Ring.build(8).unwrap(),
        quad_nodes(8, 2, 33),
        NetConfig {
            scheme: SchemeKind::Fixed,
            tol: 0.0,
            max_iters: 500,
            max_staleness: 1,
            silence_timeout: 16,
            ..Default::default()
        },
        plan,
    )
    .run();
    assert_eq!(report.iterations, 500);
    assert!(report.counters.dropped_loss > 0, "loss model must have bitten");
    assert!(report.counters.stale_reads > 0, "staleness must have been exercised");
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal < 1e-2,
            "async ADMM under 12% loss must still reach consensus, primal {}",
            last.max_primal);
    assert!(report.virtual_time > 0);
    let keep = vec![true; 8];
    assert!(spread(&report.thetas, &keep) < 5e-2,
            "final parameters must agree across nodes");
}

#[test]
fn churn_scenario_converges_with_join_and_leave() {
    // the acceptance scenario: ≥10% drop plus one scripted join and one
    // scripted leave, on a ring with a bridging extra node. The live
    // subgraph stays connected throughout.
    let mut edges: Vec<(usize, usize)> = (0..8).map(|i| (i, (i + 1) % 8)).collect();
    edges.push((8, 0));
    edges.push((8, 4));
    let graph = Graph::new(9, &edges).unwrap();
    let plan = FaultPlan {
        link: LinkModel { base: 2, jitter: 4, loss: 0.10, dup: 0.0 },
        partitions: vec![],
        churn: vec![
            ChurnEvent::Join { at: 200, node: 8 },
            ChurnEvent::Leave { at: 500, node: 3 },
        ],
        initially_dormant: vec![8],
    };
    let report = AsyncRunner::new(
        graph,
        quad_nodes(9, 2, 7),
        NetConfig {
            scheme: SchemeKind::Nap,
            tol: 0.0,
            max_iters: 600,
            max_staleness: 1,
            silence_timeout: 16,
            ..Default::default()
        },
        plan,
    )
    .run();
    assert_eq!(report.counters.joins, 1);
    assert_eq!(report.counters.leaves, 1);
    assert!(report.counters.dropped_loss > 0);
    assert!(!report.live[3], "node 3 left");
    assert!(report.live[8], "node 8 joined");
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal < 1e-2,
            "consensus among survivors, primal {}", last.max_primal);
    // survivors agree; the departed node's last θ is whatever it had
    let keep: Vec<bool> = (0..9).map(|i| i != 3).collect();
    assert!(spread(&report.thetas, &keep) < 5e-2,
            "survivor parameters must agree");
    // the trace records the churn deterministically
    assert!(report
        .trace
        .iter()
        .any(|e| e.kind == TraceKind::Join { node: 8 }));
    assert!(report
        .trace
        .iter()
        .any(|e| e.kind == TraceKind::Leave { node: 3 }));
}

#[test]
fn transient_partition_heals_and_converges() {
    let plan = FaultPlan {
        link: LinkModel { base: 1, jitter: 2, loss: 0.0, dup: 0.0 },
        partitions: vec![Partition { start: 30, end: 200, group: vec![0, 1, 2] }],
        ..FaultPlan::none()
    };
    let report = AsyncRunner::new(
        Topology::Ring.build(6).unwrap(),
        quad_nodes(6, 2, 17),
        NetConfig {
            scheme: SchemeKind::Vp,
            tol: 0.0,
            max_iters: 400,
            max_staleness: 1,
            silence_timeout: 8,
            ..Default::default()
        },
        plan,
    )
    .run();
    assert!(report.counters.dropped_partition > 0, "partition must have cut");
    assert!(report.counters.fallback_reads > 0,
            "silent-neighbour fallback must have fired during the partition");
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal < 1e-2, "post-heal consensus, primal {}",
            last.max_primal);
}

#[test]
fn nap_activity_rule_masks_and_run_completes() {
    // with the effective-topology rule enabled on a dense graph, the run
    // must stay finite and consistent whether or not edges get masked;
    // masking events, when they happen, appear in trace and counters
    let report = AsyncRunner::new(
        Topology::Complete.build(6).unwrap(),
        quad_nodes(6, 2, 13),
        NetConfig {
            scheme: SchemeKind::Nap,
            tol: 0.0,
            max_iters: 150,
            activity: Some(ActivityConfig {
                off_below: 0.6,
                on_above: 0.95,
                patience: 2,
            }),
            ..Default::default()
        },
        FaultPlan::none(),
    )
    .run();
    assert_eq!(report.iterations, 150);
    for th in &report.thetas {
        assert!(th.iter().all(|x| x.is_finite()));
    }
    let offs = report
        .trace
        .iter()
        .filter(|e| matches!(e.kind, TraceKind::EdgeOff { .. }))
        .count() as u64;
    assert_eq!(offs, report.counters.edges_deactivated);
    let ons = report
        .trace
        .iter()
        .filter(|e| matches!(e.kind, TraceKind::EdgeOn { .. }))
        .count() as u64;
    assert_eq!(ons, report.counters.edges_reactivated);
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal.is_finite());
}

// -- satellite: lag-aware λ damping ------------------------------------------

#[test]
fn lag_damping_is_bit_identical_when_no_read_lags() {
    // zero faults + lock-step: no read ever resolves stale, so the
    // damping branch never fires and the flag is bit-transparent
    let run = |damp: bool| {
        AsyncRunner::new(
            Topology::Ring.build(6).unwrap(),
            quad_nodes(6, 3, 5),
            NetConfig {
                scheme: SchemeKind::Ap,
                tol: 1e-4,
                max_iters: 60,
                seed: 11,
                lag_damping: damp,
                ..Default::default()
            },
            FaultPlan::none(),
        )
        .run()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.thetas, on.thetas);
    assert_eq!(off.iterations, on.iterations);
    assert_eq!(off.recorder.stats.len(), on.recorder.stats.len());
    for (a, b) in off.recorder.stats.iter().zip(&on.recorder.stats) {
        assert_stats_bit_equal(a, b);
    }
}

#[test]
fn lag_damping_tames_the_over_budget_staleness_cell() {
    // the stale3 regime (systematic 3-round lag under loss) destabilizes
    // the undamped dual accumulation; scaling stale steps by 1/(1+lag)
    // must leave the damped run no worse — and finite
    let run = |damp: bool| {
        AsyncRunner::new(
            Topology::Ring.build(8).unwrap(),
            quad_nodes(8, 2, 33),
            NetConfig {
                scheme: SchemeKind::Fixed,
                tol: 0.0,
                max_iters: 300,
                seed: 5,
                max_staleness: 3,
                silence_timeout: 16,
                lag_damping: damp,
                tracing: false,
                ..Default::default()
            },
            FaultPlan {
                link: LinkModel { base: 2, jitter: 4, loss: 0.10, dup: 0.02 },
                ..FaultPlan::none()
            },
        )
        .run()
    };
    let undamped = run(false);
    let damped = run(true);
    assert!(damped.counters.stale_reads > 0, "budget must actually be used");
    let pu = undamped.recorder.stats.last().unwrap().max_primal;
    let pd = damped.recorder.stats.last().unwrap().max_primal;
    assert!(pd.is_finite(), "damped run must stay finite");
    assert!(pd < pu || pd < 1e-2,
            "damping must not be worse than the raw stale3 cell: {pd} vs {pu}");
}

// -- satellite: skip-λ-on-fallback (the complementary kernel policy) ---------

#[test]
fn skip_lambda_is_bit_identical_when_no_read_falls_back() {
    // zero faults + lock-step: no read is ever forced past the budget, so
    // the skip branch never fires and the flag is bit-transparent
    let run = |skip: bool| {
        AsyncRunner::new(
            Topology::Ring.build(6).unwrap(),
            quad_nodes(6, 3, 5),
            NetConfig {
                scheme: SchemeKind::Nap,
                tol: 1e-4,
                max_iters: 60,
                seed: 11,
                skip_lambda_on_fallback: skip,
                ..Default::default()
            },
            FaultPlan::none(),
        )
        .run()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.thetas, on.thetas);
    assert_eq!(off.iterations, on.iterations);
    assert_eq!(off.recorder.stats.len(), on.recorder.stats.len());
    for (a, b) in off.recorder.stats.iter().zip(&on.recorder.stats) {
        assert_stats_bit_equal(a, b);
    }
}

#[test]
fn skip_lambda_tames_the_over_budget_staleness_cell() {
    // the stale3 regime again (cf. the damping test): dropping the λ
    // increments of forced fallback reads must leave the run no worse
    // than the raw over-budget cell — and finite
    let run = |skip: bool| {
        AsyncRunner::new(
            Topology::Ring.build(8).unwrap(),
            quad_nodes(8, 2, 33),
            NetConfig {
                scheme: SchemeKind::Fixed,
                tol: 0.0,
                max_iters: 300,
                seed: 5,
                max_staleness: 3,
                silence_timeout: 16,
                skip_lambda_on_fallback: skip,
                tracing: false,
                ..Default::default()
            },
            FaultPlan {
                link: LinkModel { base: 2, jitter: 4, loss: 0.10, dup: 0.02 },
                ..FaultPlan::none()
            },
        )
        .run()
    };
    let raw = run(false);
    let skipped = run(true);
    assert!(skipped.counters.stale_reads > 0, "budget must actually be used");
    let pr = raw.recorder.stats.last().unwrap().max_primal;
    let ps = skipped.recorder.stats.last().unwrap().max_primal;
    assert!(ps.is_finite(), "skip run must stay finite");
    assert!(ps < pr || ps < 1e-2,
            "skipping must not be worse than the raw stale3 cell: {ps} vs {pr}");
}

// -- satellite: async-friendly app-metric hook -------------------------------

#[test]
fn dppca_runs_through_async_runtime_with_app_metric() {
    // the ROADMAP item: D-PPCA (not just quadratic consensus) through the
    // net runtime, scored by the subspace-angle hook under 10% loss
    use crate::data::{even_split, SubspaceSpec};
    use crate::dppca::DppcaSolver;
    use crate::experiments::common::{max_angle_vs_reference, BackendChoice};
    use crate::util::rng::Pcg;

    let spec = SubspaceSpec { d: 6, m: 2, n: 48, noise_var: 0.05, random_mean: false };
    let data = spec.generate(&mut Pcg::seed(4));
    let part = even_split(48, 4);
    let backend = BackendChoice::Native.build().unwrap();
    let solvers: Vec<DppcaSolver> = part
        .ranges
        .iter()
        .map(|&(lo, hi)| {
            DppcaSolver::from_block(data.x.col_slice(lo, hi), 2, backend.clone())
                .unwrap()
        })
        .collect();
    let w_true = data.w_true.clone();
    let report = AsyncRunner::new(
        Topology::Ring.build(4).unwrap(),
        solvers,
        NetConfig {
            scheme: SchemeKind::Ap,
            tol: 1e-5,
            max_iters: 200,
            seed: 2,
            max_staleness: 1,
            silence_timeout: 16,
            tracing: false,
            ..Default::default()
        },
        FaultPlan {
            link: LinkModel { base: 2, jitter: 4, loss: 0.10, dup: 0.0 },
            ..FaultPlan::none()
        },
    )
    .with_app_metric(move |_round, thetas, live| {
        // no churn in this scenario: every snapshot slot stays current
        assert!(live.iter().all(|&l| l));
        max_angle_vs_reference(thetas, 6, 2, &w_true)
    })
    .run();
    assert!(report.counters.dropped_loss > 0, "loss model must have bitten");
    assert!(report.recorder.stats.iter().all(|s| s.app_error.is_finite()));
    let curve = report.recorder.error_curve();
    assert!(curve.last().unwrap() < &curve[0],
            "subspace angle must improve under loss: {} → {}",
            curve[0], curve.last().unwrap());
}

#[test]
fn staleness_budget_allows_run_ahead_under_jitter() {
    // pure latency jitter, no loss: with a one-round staleness budget the
    // nodes overlap rounds (stale reads observed) yet both the strict and
    // the relaxed run still reach internal consensus. The two runs land
    // on *different* consensus points — stale reads bias the dual
    // accumulation, shifting the async fixed point — which is expected
    // and why the budget is a scenario knob, not a free lunch. (Budgets
    // ≥ 2 rounds of systematic lag can destabilize the dual update
    // entirely; the net_scenarios `stale3` cell demonstrates it.)
    let jittery = || FaultPlan {
        link: LinkModel { base: 1, jitter: 6, loss: 0.0, dup: 0.0 },
        ..FaultPlan::none()
    };
    let run = |stale: u64| {
        AsyncRunner::new(
            Topology::Ring.build(6).unwrap(),
            quad_nodes(6, 2, 29),
            NetConfig {
                scheme: SchemeKind::Ap,
                tol: 0.0,
                max_iters: 300,
                max_staleness: stale,
                silence_timeout: 32,
                ..Default::default()
            },
            jittery(),
        )
        .run()
    };
    let strict = run(0);
    let relaxed = run(1);
    assert!(relaxed.counters.stale_reads > 0,
            "staleness budget must actually be used under jitter");
    let keep = vec![true; 6];
    for report in [&strict, &relaxed] {
        let last = report.recorder.stats.last().unwrap();
        assert!(last.max_primal < 1e-2, "primal {}", last.max_primal);
        assert!(spread(&report.thetas, &keep) < 5e-2);
    }
}
