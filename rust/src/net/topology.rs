//! Live topology control: scripted churn and the NAP effective-topology
//! mapping.
//!
//! The paper observes that NAP's per-edge penalty budgets "effectively
//! lead to an adaptive, dynamic network topology" (Fig. 1c: edges whose
//! penalty influence collapses become "dotted" — still drawn, barely
//! coupling). This module makes that story *operational*: the
//! [`TopologyController`] owns the run's [`LiveView`] and turns two kinds
//! of decisions into mask mutations —
//!
//! * **scripted churn** ([`crate::net::ChurnEvent`]s popped from the
//!   simulator): a `Leave` deactivates the node and every incident edge; a
//!   `Join` activates the node and its edges toward live neighbours;
//! * **edge activity** (optional, [`ActivityConfig`]): each time a node
//!   publishes fresh penalties, the controller recomputes every incident
//!   undirected edge's *influence* — its symmetrized penalty η̄_ij divided
//!   by the mean η̄ over currently-eligible edges — and deactivates edges
//!   whose influence has stayed below `off_below` for `patience`
//!   consecutive observations (hysteresis: reactivation needs `on_above`).
//!   A deactivated edge stops carrying messages and drops out of both
//!   endpoints' solves, λ updates and η̄ normalizations; this is exactly
//!   the "weakly influencing edge" of the paper made physical. Because
//!   η̄ is symmetrized, a one-sided penalty collapse (AP emphasizing one
//!   direction) keeps the edge's influence near ½ — masking requires both
//!   directions to agree the edge is idle.
//!
//! Degree-dependent quantities stay correct by construction because every
//! consumer reads degrees through [`LiveView::live_degree`]; a node whose
//! last edge deactivates would take the isolated-node semantics (η̄ = 0)
//! shared by both synchronous runtimes since PR 2 — to keep consensus
//! reachable the activity rule therefore never masks a node's last live
//! edge.

use crate::graph::{Graph, LiveView, NodeId};

use super::sim::TraceKind;
use super::transport::Transport;

/// Hysteresis thresholds for the NAP effective-topology mapping. All
/// ratios are relative to the mean symmetrized penalty over eligible
/// edges.
#[derive(Debug, Clone, Copy)]
pub struct ActivityConfig {
    /// deactivate when influence < `off_below` for `patience` consecutive
    /// observations of that edge
    pub off_below: f64,
    /// reactivate when a masked edge's influence recovers above this
    pub on_above: f64,
    /// consecutive low-influence observations required before masking
    pub patience: u32,
}

impl Default for ActivityConfig {
    fn default() -> Self {
        // conservative defaults: only persistent near-zero influence masks
        // an edge, and recovery to ~mean level restores it
        ActivityConfig { off_below: 0.05, on_above: 0.5, patience: 3 }
    }
}

/// Applies churn + edge-activity decisions to the run's [`LiveView`].
pub struct TopologyController {
    view: LiveView,
    activity: Option<ActivityConfig>,
    /// undirected edge list (i < j), index-aligned with the streak/mask
    /// bookkeeping below
    edges: Vec<(NodeId, NodeId)>,
    /// slot_to_edge[node][slot] → undirected edge id
    slot_to_edge: Vec<Vec<usize>>,
    /// latest published directed η per (node, slot)
    eta_dir: Vec<Vec<f64>>,
    below_streak: Vec<u32>,
    /// edges currently masked *by the activity rule* (churn-masked edges
    /// are not ours to reactivate)
    activity_masked: Vec<bool>,
}

impl TopologyController {
    pub fn new(graph: Graph, activity: Option<ActivityConfig>) -> TopologyController {
        let n = graph.len();
        let mut edges: Vec<(NodeId, NodeId)> =
            graph.directed_edges().filter(|&(a, b)| a < b).collect();
        edges.sort_unstable();
        let mut slot_to_edge: Vec<Vec<usize>> =
            (0..n).map(|i| vec![usize::MAX; graph.degree(i)]).collect();
        for (eid, &(a, b)) in edges.iter().enumerate() {
            let sa = graph.edge_slot(a, b).expect("edge exists");
            let sb = graph.edge_slot(b, a).expect("graph symmetry");
            slot_to_edge[a][sa] = eid;
            slot_to_edge[b][sb] = eid;
        }
        let eta_dir = (0..n).map(|i| vec![0.0; graph.degree(i)]).collect();
        let m = edges.len();
        TopologyController {
            view: LiveView::new(graph),
            activity,
            edges,
            slot_to_edge,
            eta_dir,
            below_streak: vec![0; m],
            activity_masked: vec![false; m],
        }
    }

    pub fn view(&self) -> &LiveView {
        &self.view
    }

    pub fn view_mut(&mut self) -> &mut LiveView {
        &mut self.view
    }

    /// Apply a scripted join. Returns false if the node was already live
    /// (the event is then a no-op the caller should skip).
    pub fn apply_join<T: Transport>(&mut self, node: NodeId, net: &mut T) -> bool {
        if self.view.node_live(node) {
            return false;
        }
        self.view.set_node(node, true);
        // set_node restored every edge toward live neighbours — re-apply
        // the activity rule's masks, or a rejoin would silently resurrect
        // edges the rule still holds deactivated (desyncing
        // `activity_masked` from the view)
        let degree = self.view.graph().degree(node);
        for slot in 0..degree {
            let eid = self.slot_to_edge[node][slot];
            if self.activity_masked[eid] {
                let (a, b) = self.edges[eid];
                self.view.set_edge(a, b, false);
            }
        }
        net.counters().joins += 1;
        net.record(TraceKind::Join { node });
        true
    }

    /// Apply a scripted leave. Returns false if the node was already dead.
    pub fn apply_leave<T: Transport>(&mut self, node: NodeId, net: &mut T) -> bool {
        if !self.view.node_live(node) {
            return false;
        }
        self.view.set_node(node, false);
        net.counters().leaves += 1;
        net.record(TraceKind::Leave { node });
        true
    }

    /// Record node `i`'s freshly published out-edge penalties and, if the
    /// activity rule is enabled, re-evaluate the influence of its incident
    /// edges. Returns the edges toggled this call (endpoint pairs), so the
    /// runner can wake blocked neighbours.
    pub fn observe_etas<T: Transport>(&mut self, i: NodeId, etas: &[f64], net: &mut T)
                        -> Vec<(NodeId, NodeId)> {
        debug_assert_eq!(etas.len(), self.eta_dir[i].len());
        self.eta_dir[i].copy_from_slice(etas);
        let Some(cfg) = self.activity else {
            return Vec::new();
        };

        // mean symmetrized penalty over eligible edges: both endpoints
        // live, and the edge either active or masked by us (it must be
        // able to re-enter the comparison)
        let mut sum = 0.0;
        let mut count = 0usize;
        for (eid, &(a, b)) in self.edges.iter().enumerate() {
            if !self.view.node_live(a) || !self.view.node_live(b) {
                continue;
            }
            let sa = self.view.graph().edge_slot(a, b).expect("edge exists");
            if !self.view.slot_live(a, sa) && !self.activity_masked[eid] {
                continue; // churn-masked, not ours
            }
            sum += self.eta_bar(a, b);
            count += 1;
        }
        if count == 0 || sum <= 0.0 {
            return Vec::new();
        }
        let mean = sum / count as f64;

        // re-evaluate only the edges incident to i (the publishing node)
        let mut toggled = Vec::new();
        let degree = self.view.graph().degree(i);
        for slot in 0..degree {
            let eid = self.slot_to_edge[i][slot];
            let (a, b) = self.edges[eid];
            let j = if a == i { b } else { a };
            if !self.view.node_live(a) || !self.view.node_live(b) {
                continue;
            }
            let sa = self.view.graph().edge_slot(a, b).expect("edge exists");
            let churn_masked = !self.view.slot_live(a, sa) && !self.activity_masked[eid];
            if churn_masked {
                continue;
            }
            let influence = self.eta_bar(a, b) / mean;
            if self.activity_masked[eid] {
                if influence > cfg.on_above {
                    self.activity_masked[eid] = false;
                    self.below_streak[eid] = 0;
                    self.view.set_edge(a, b, true);
                    net.counters().edges_reactivated += 1;
                    net.record(TraceKind::EdgeOn { a, b });
                    toggled.push((a, b));
                }
            } else if influence < cfg.off_below {
                self.below_streak[eid] += 1;
                // never disconnect a node's last live edge: a fully
                // isolated node would stop moving toward consensus
                if self.below_streak[eid] >= cfg.patience
                    && self.view.live_degree(i) > 1
                    && self.view.live_degree(j) > 1
                {
                    self.activity_masked[eid] = true;
                    self.view.set_edge(a, b, false);
                    net.counters().edges_deactivated += 1;
                    net.record(TraceKind::EdgeOff { a, b });
                    toggled.push((a, b));
                }
            } else {
                self.below_streak[eid] = 0;
            }
        }
        toggled
    }

    /// Symmetrized penalty η̄_ab = (η_{a→b} + η_{b→a}) / 2 from the latest
    /// published values.
    fn eta_bar(&self, a: NodeId, b: NodeId) -> f64 {
        let sa = self.view.graph().edge_slot(a, b).expect("edge exists");
        let sb = self.view.graph().edge_slot(b, a).expect("graph symmetry");
        0.5 * (self.eta_dir[a][sa] + self.eta_dir[b][sb])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;
    use crate::net::sim::{FaultPlan, NetSim};

    fn sim() -> NetSim {
        NetSim::new(0, FaultPlan::none(), false)
    }

    #[test]
    fn churn_round_trip() {
        let mut ctrl = TopologyController::new(Topology::Ring.build(5).unwrap(), None);
        let mut s = sim();
        assert!(ctrl.apply_leave(2, &mut s));
        assert!(!ctrl.apply_leave(2, &mut s), "idempotent");
        assert_eq!(ctrl.view().live_degree(1), 1);
        assert!(ctrl.apply_join(2, &mut s));
        assert_eq!(ctrl.view().live_degree(1), 2);
        assert_eq!(s.counters.leaves, 1);
        assert_eq!(s.counters.joins, 1);
    }

    #[test]
    fn low_influence_edge_deactivates_after_patience() {
        let g = Topology::Complete.build(4).unwrap();
        let mut ctrl = TopologyController::new(
            g,
            Some(ActivityConfig { off_below: 0.2, on_above: 0.8, patience: 2 }),
        );
        let mut s = sim();
        // warm-up: everyone publishes uniform strong penalties
        for i in 0..4 {
            ctrl.observe_etas(i, &[10.0, 10.0, 10.0], &mut s);
        }
        // the {0,1} edge collapses from BOTH sides (η̄ is symmetrized, so a
        // one-sided collapse keeps the edge's influence near ½ — by design
        // it must not mask). Slot 0 of node 0 is neighbour 1 and slot 0 of
        // node 1 is neighbour 0 (sorted adjacency).
        let weak = [0.01, 10.0, 10.0];
        ctrl.observe_etas(1, &weak, &mut s);
        let t1 = ctrl.observe_etas(0, &weak, &mut s);
        assert!(t1.is_empty(), "patience 2: first low observation only streaks");
        let t2 = ctrl.observe_etas(0, &weak, &mut s);
        assert_eq!(t2, vec![(0, 1)]);
        assert_eq!(s.counters.edges_deactivated, 1);
        let slot = ctrl.view().graph().edge_slot(0, 1).unwrap();
        assert!(!ctrl.view().slot_live(0, slot));
        assert_eq!(ctrl.view().live_degree(0), 2);

        // recovery: both directions strong again → reactivates (the first
        // one-sided strong publish leaves influence ≈ ½ < on_above)
        let strong = [10.0, 10.0, 10.0];
        let t3 = ctrl.observe_etas(0, &strong, &mut s);
        assert!(t3.is_empty(), "half-recovered edge stays masked");
        let t4 = ctrl.observe_etas(1, &strong, &mut s);
        assert_eq!(t4, vec![(0, 1)]);
        assert_eq!(s.counters.edges_reactivated, 1);
        assert!(ctrl.view().slot_live(0, slot));
    }

    #[test]
    fn last_live_edge_is_never_masked() {
        let g = Topology::Chain.build(3).unwrap(); // 0-1-2
        let mut ctrl = TopologyController::new(
            g,
            Some(ActivityConfig { off_below: 0.9, on_above: 2.0, patience: 1 }),
        );
        let mut s = sim();
        ctrl.observe_etas(1, &[10.0, 10.0], &mut s);
        ctrl.observe_etas(2, &[10.0], &mut s);
        // node 0's only edge looks weak, but masking it would isolate 0
        let toggled = ctrl.observe_etas(0, &[0.001], &mut s);
        assert!(toggled.is_empty());
        assert_eq!(ctrl.view().live_degree(0), 1);
    }

    #[test]
    fn rejoin_preserves_activity_masks() {
        // a leave/rejoin cycle must not resurrect an edge the activity
        // rule still holds deactivated (set_node restores every edge;
        // apply_join re-applies the rule's masks on top)
        let g = Topology::Complete.build(4).unwrap();
        let mut ctrl = TopologyController::new(
            g,
            Some(ActivityConfig { off_below: 0.2, on_above: 0.8, patience: 1 }),
        );
        let mut s = sim();
        for i in 0..4 {
            ctrl.observe_etas(i, &[10.0, 10.0, 10.0], &mut s);
        }
        let weak = [0.01, 10.0, 10.0];
        ctrl.observe_etas(1, &weak, &mut s);
        ctrl.observe_etas(0, &weak, &mut s);
        let slot = ctrl.view().graph().edge_slot(0, 1).unwrap();
        assert!(!ctrl.view().slot_live(0, slot), "edge {{0,1}} activity-masked");

        ctrl.apply_leave(0, &mut s);
        ctrl.apply_join(0, &mut s);
        assert!(!ctrl.view().slot_live(0, slot),
                "rejoin must keep the activity-masked edge off");
        assert_eq!(ctrl.view().live_degree(0), 2,
                   "the other edges are restored");

        // and the rule can still reactivate it through the normal path
        let strong = [10.0, 10.0, 10.0];
        ctrl.observe_etas(0, &strong, &mut s);
        let t = ctrl.observe_etas(1, &strong, &mut s);
        assert_eq!(t, vec![(0, 1)]);
        assert!(ctrl.view().slot_live(0, slot));
    }

    #[test]
    fn churn_masked_edges_are_not_activity_candidates() {
        let g = Topology::Ring.build(4).unwrap();
        let mut ctrl = TopologyController::new(
            g,
            Some(ActivityConfig { off_below: 0.5, on_above: 0.9, patience: 1 }),
        );
        let mut s = sim();
        ctrl.apply_leave(1, &mut s);
        for i in [0usize, 2, 3] {
            ctrl.observe_etas(i, &[10.0, 10.0], &mut s);
        }
        // edges to the dead node never toggle, live edges unaffected
        assert_eq!(s.counters.edges_deactivated, 0);
        assert_eq!(ctrl.view().live_degree(0), 1);
    }
}
