//! E4 — the paper's Hopkins-155 study (§5.2, text table): mean iterations
//! to convergence over a 135-object trajectory corpus, objects whose
//! subspace-angle error exceeds 15° excluded (non-rigid sequences), 5
//! random restarts per object; complete and ring networks of 5 cameras.
//!
//! Paper reference points: ADMM-VP ≈ 40.2% and ADMM-VP+AP ≈ 37.3% fewer
//! iterations than baseline ADMM on the complete network; smaller gains
//! on the ring; AP/NAP ≈ baseline because the baseline already converges
//! in < 100 iterations.

use std::path::Path;

use super::common::{paper_schemes, run_dppca, BackendChoice, DppcaSpec};
use crate::data::{TrajectoryCorpus, TrajectoryObject};
use crate::error::Result;
use crate::graph::Topology;
use crate::dppca::InitStrategy;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::sfm;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::stats;

pub const CAMERAS: usize = 5;
/// The paper's exclusion threshold (degrees).
pub const EXCLUDE_DEG: f64 = 15.0;

#[derive(Debug, Clone)]
pub struct HopkinsConfig {
    /// corpus size (paper: 135)
    pub objects: usize,
    pub seeds: usize,
    pub backend: BackendChoice,
    pub max_iters: usize,
    pub schemes: Vec<SchemeKind>,
    pub topologies: Vec<Topology>,
    pub data_seed: u64,
    /// fraction of deliberately non-rigid objects
    pub degenerate_frac: f64,
}

impl Default for HopkinsConfig {
    fn default() -> Self {
        HopkinsConfig {
            objects: 135,
            seeds: 5,
            backend: BackendChoice::Native,
            max_iters: 400,
            schemes: paper_schemes().to_vec(),
            topologies: vec![Topology::Complete, Topology::Ring],
            data_seed: 0,
            degenerate_frac: 0.1,
        }
    }
}

/// Per (topology, scheme) aggregate.
#[derive(Debug, Clone)]
pub struct HopkinsRow {
    pub topology: &'static str,
    pub scheme: SchemeKind,
    pub mean_iterations: f64,
    /// speed-up vs the fixed-penalty baseline, percent
    pub speedup_pct: f64,
    pub objects_used: usize,
    pub objects_excluded: usize,
}

/// One object under one (topology, scheme): mean iterations over restarts,
/// or None if the object fails the 15° filter.
fn run_one(obj: &TrajectoryObject, topo: Topology, scheme: SchemeKind,
           cfg: &HopkinsConfig, backend: &crate::runtime::SharedBackend)
           -> Result<Option<f64>> {
    let data = sfm::ppca_input(&obj.measurements);
    let (baseline, _) = sfm::svd_structure(&obj.measurements)?;
    let blocks = sfm::split_frames(&data, obj.frames, CAMERAS);
    let n_padded = blocks.iter().map(|b| b.cols()).max().unwrap();
    let graph = topo.build(CAMERAS)?;
    let mut iters = Vec::with_capacity(cfg.seeds);
    let mut angles = Vec::with_capacity(cfg.seeds);
    for seed in 0..cfg.seeds as u64 {
        let mut spec = DppcaSpec::new(blocks.clone(), n_padded, 3, graph.clone(), scheme);
        spec.params = SchemeParams::default();
        spec.init = InitStrategy::LocalPca;
        spec.seed = seed;
        spec.max_iters = cfg.max_iters;
        spec.reference = Some(&baseline);
        let result = run_dppca(&spec, backend.clone())?;
        iters.push(result.iterations as f64);
        angles.push(result.final_angle);
    }
    // the paper omits objects yielding > 15° (median over restarts here)
    if stats::median(&angles) > EXCLUDE_DEG {
        return Ok(None);
    }
    Ok(Some(stats::mean(&iters)))
}

/// Full corpus sweep; writes per-object and summary CSVs.
pub fn run(cfg: &HopkinsConfig, out_dir: &Path) -> Result<Vec<HopkinsRow>> {
    let backend = cfg.backend.build()?;
    let corpus = TrajectoryCorpus::generate(cfg.objects, cfg.degenerate_frac,
                                            cfg.data_seed);
    let mut detail = CsvWriter::create(
        out_dir.join("hopkins_objects.csv"),
        &["object", "topology", "scheme", "mean_iters", "excluded"],
    )?;
    let mut rows = Vec::new();
    for &topo in &cfg.topologies {
        // baseline first (speed-up denominator)
        let mut baseline_mean = f64::NAN;
        for &scheme in &cfg.schemes {
            let mut used = Vec::new();
            let mut excluded = 0usize;
            for obj in &corpus.objects {
                match run_one(obj, topo, scheme, cfg, &backend)? {
                    Some(mean_iters) => {
                        detail.row(&[obj.id.to_string(), topo.name().to_string(),
                                     scheme.name().to_string(), fnum(mean_iters),
                                     "0".to_string()])?;
                        used.push(mean_iters);
                    }
                    None => {
                        excluded += 1;
                        detail.row(&[obj.id.to_string(), topo.name().to_string(),
                                     scheme.name().to_string(), "nan".to_string(),
                                     "1".to_string()])?;
                    }
                }
            }
            let mean = stats::mean(&used);
            if scheme == SchemeKind::Fixed {
                baseline_mean = mean;
            }
            let speedup = if scheme == SchemeKind::Fixed {
                0.0
            } else if baseline_mean.is_finite() {
                (baseline_mean - mean) / baseline_mean * 100.0
            } else {
                f64::NAN
            };
            rows.push(HopkinsRow {
                topology: topo.name(),
                scheme,
                mean_iterations: mean,
                speedup_pct: speedup,
                objects_used: used.len(),
                objects_excluded: excluded,
            });
        }
    }
    detail.finish()?;
    let mut w = CsvWriter::create(out_dir.join("hopkins_summary.csv"),
                                  &["topology", "scheme", "mean_iters",
                                    "speedup_pct", "objects_used", "excluded"])?;
    for r in &rows {
        w.row(&[r.topology.to_string(), r.scheme.name().to_string(),
                fnum(r.mean_iterations), fnum(r.speedup_pct),
                r.objects_used.to_string(), r.objects_excluded.to_string()])?;
    }
    w.finish()?;
    Ok(rows)
}

pub fn print_summary(rows: &[HopkinsRow]) {
    println!("{:<10} {:<12} {:>12} {:>12} {:>8} {:>9}", "topology", "scheme",
             "mean iters", "speedup %", "used", "excluded");
    for r in rows {
        println!("{:<10} {:<12} {:>12.1} {:>12.1} {:>8} {:>9}", r.topology,
                 r.scheme.name(), r.mean_iterations, r.speedup_pct,
                 r.objects_used, r.objects_excluded);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_corpus_runs_and_excludes_degenerates() {
        let dir = std::env::temp_dir().join("fadmm_hopkins_test");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = HopkinsConfig {
            objects: 6,
            seeds: 1,
            max_iters: 200,
            schemes: vec![SchemeKind::Fixed, SchemeKind::Vp],
            topologies: vec![Topology::Complete],
            degenerate_frac: 0.35,
            ..Default::default()
        };
        let rows = run(&cfg, &dir).unwrap();
        assert_eq!(rows.len(), 2);
        let fixed = &rows[0];
        assert_eq!(fixed.scheme, SchemeKind::Fixed);
        assert!(fixed.speedup_pct.abs() < 1e-9, "baseline vs itself");
        assert!(fixed.objects_used + fixed.objects_excluded == 6);
        assert!(fixed.objects_used > 0, "rigid objects must pass the filter");
        assert!(dir.join("hopkins_summary.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
