//! E-cluster — the machines × loss × collective × scheme matrix over the
//! hybrid cluster runtime.
//!
//! Every cell runs the same seeded quadratic consensus problem through
//! [`ClusterRunner`] and through the single-box [`ShardedRunner`] oracle
//! (whose leader fold is the omniscient reduction the collectives
//! replace), and reports **extra rounds vs oracle** — how many more
//! rounds to the stop criterion the tree or gossip reduction costs under
//! each loss level. By the cluster parity contracts, the `tree`
//! collective at zero faults is bit-identical to the oracle, so its
//! extra-rounds cell is exactly 0 and every non-zero entry is
//! attributable to injected faults or (for `gossip`) estimator error.

use std::collections::BTreeMap;
use std::path::Path;

use crate::cluster::{ClusterConfig, ClusterRunner, CollectiveKind};
use crate::coordinator::{ShardedConfig, ShardedRunner};
use crate::error::Result;
use crate::graph::{Graph, Topology};
use crate::net::{FaultPlan, LinkModel};
use crate::penalty::SchemeKind;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::stats;

use super::common::quad_problem_factory;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct ClusterScenarioConfig {
    /// ring size
    pub nodes: usize,
    /// machine counts to sweep
    pub machines_list: Vec<usize>,
    pub seeds: usize,
    pub max_iters: usize,
    pub schemes: Vec<SchemeKind>,
    /// Bernoulli loss levels (0.0 ⇒ the zero-fault cell)
    pub loss_levels: Vec<f64>,
    pub collectives: Vec<CollectiveKind>,
}

impl Default for ClusterScenarioConfig {
    fn default() -> Self {
        ClusterScenarioConfig {
            nodes: 24,
            machines_list: vec![2, 4],
            seeds: 3,
            max_iters: 300,
            schemes: SchemeKind::ALL.to_vec(),
            loss_levels: vec![0.0, 0.10, 0.30],
            collectives: CollectiveKind::ALL.to_vec(),
        }
    }
}

/// One (machines, scenario, collective, scheme) summary row (seed medians).
#[derive(Debug, Clone)]
pub struct ClusterScenarioRow {
    pub machines: usize,
    pub collective: CollectiveKind,
    pub scheme: SchemeKind,
    pub scenario: String,
    pub median_rounds: f64,
    pub median_oracle_rounds: f64,
    /// median over seeds of (cluster rounds − oracle rounds)
    pub median_extra_rounds: f64,
    pub median_virtual_time: f64,
    pub median_final_primal: f64,
    pub converged_fraction: f64,
    pub median_dropped: f64,
    pub median_collective_timeouts: f64,
    pub median_gossip_ticks: f64,
}

fn loss_plan(loss: f64) -> FaultPlan {
    if loss <= 0.0 {
        FaultPlan::none()
    } else {
        FaultPlan {
            link: LinkModel { base: 2, jitter: 4, loss, dup: 0.02 },
            ..FaultPlan::none()
        }
    }
}

const TOL: f64 = 1e-6;
const DIM: usize = 3;

fn scenario_graph(n: usize) -> Result<Graph> {
    Topology::Ring.build(n)
}

/// Run the full sweep, write `cluster_scenarios.csv` under `out_dir`.
pub fn run(cfg: &ClusterScenarioConfig, out_dir: &Path)
           -> Result<Vec<ClusterScenarioRow>> {
    let scenarios: Vec<(String, FaultPlan)> = cfg
        .loss_levels
        .iter()
        .map(|&l| {
            let name = if l <= 0.0 {
                "zero".to_string()
            } else {
                format!("loss{:.0}", l * 100.0)
            };
            (name, loss_plan(l))
        })
        .collect();
    run_scenarios(cfg, &scenarios, out_dir)
}

/// Replay one JSON-recorded machine-level plan across the matrix
/// (`repro cluster --plan foo.json`; ids in the plan are machine ids).
pub fn run_plan(cfg: &ClusterScenarioConfig, plan: FaultPlan, out_dir: &Path)
                -> Result<Vec<ClusterScenarioRow>> {
    run_scenarios(cfg, &[("plan".to_string(), plan)], out_dir)
}

fn run_scenarios(cfg: &ClusterScenarioConfig,
                 scenarios: &[(String, FaultPlan)], out_dir: &Path)
                 -> Result<Vec<ClusterScenarioRow>> {
    // oracle rounds per (machines, scheme, seed): the sharded runner with
    // workers = machines folds the identical shard partials omnisciently
    let mut oracle: BTreeMap<(usize, usize, u64), f64> = BTreeMap::new();
    let scheme_index =
        |s: SchemeKind| SchemeKind::ALL.iter().position(|&k| k == s).unwrap();
    for &machines in &cfg.machines_list {
        for &scheme in &cfg.schemes {
            for seed in 0..cfg.seeds as u64 {
                let report = ShardedRunner::new(
                    scenario_graph(cfg.nodes)?,
                    ShardedConfig {
                        scheme,
                        tol: TOL,
                        max_iters: cfg.max_iters,
                        seed,
                        workers: machines,
                        ..Default::default()
                    },
                )
                .run(quad_problem_factory(cfg.nodes, DIM, 1000 + seed))?;
                oracle.insert((machines, scheme_index(scheme), seed),
                              report.iterations as f64);
            }
        }
    }

    let mut rows = Vec::new();
    for &machines in &cfg.machines_list {
        for (scenario_name, plan) in scenarios {
            let faulty = plan.link.loss > 0.0
                || !plan.partitions.is_empty()
                || !plan.churn.is_empty();
            for &collective in &cfg.collectives {
                for &scheme in &cfg.schemes {
                    let mut rounds = Vec::with_capacity(cfg.seeds);
                    let mut extras = Vec::with_capacity(cfg.seeds);
                    let mut oracles = Vec::with_capacity(cfg.seeds);
                    let mut vtimes = Vec::with_capacity(cfg.seeds);
                    let mut primals = Vec::with_capacity(cfg.seeds);
                    let mut dropped = Vec::with_capacity(cfg.seeds);
                    let mut ctimeouts = Vec::with_capacity(cfg.seeds);
                    let mut gticks = Vec::with_capacity(cfg.seeds);
                    let mut converged = 0usize;
                    for seed in 0..cfg.seeds as u64 {
                        let runner = ClusterRunner::new(
                            scenario_graph(cfg.nodes)?,
                            ClusterConfig {
                                scheme,
                                tol: TOL,
                                max_iters: cfg.max_iters,
                                seed,
                                machines,
                                workers: 1,
                                collective,
                                max_staleness: if faulty { 1 } else { 0 },
                                silence_timeout: 16,
                                collective_timeout: 24,
                                fallback_after: 2,
                                tracing: false,
                                ..Default::default()
                            },
                            plan.clone(),
                            quad_problem_factory(cfg.nodes, DIM, 1000 + seed),
                        )?;
                        let report = runner.run();
                        let base =
                            oracle[&(machines, scheme_index(scheme), seed)];
                        rounds.push(report.iterations as f64);
                        oracles.push(base);
                        extras.push(report.iterations as f64 - base);
                        vtimes.push(report.virtual_time as f64);
                        primals.push(report
                            .recorder
                            .stats
                            .last()
                            .map(|s| s.max_primal)
                            .unwrap_or(f64::NAN));
                        dropped.push(report.counters.dropped_total() as f64);
                        ctimeouts.push(report.counters.collective_timeouts as f64);
                        gticks.push(report.counters.gossip_ticks as f64);
                        if report.converged {
                            converged += 1;
                        }
                    }
                    rows.push(ClusterScenarioRow {
                        machines,
                        collective,
                        scheme,
                        scenario: scenario_name.clone(),
                        median_rounds: stats::median(&rounds),
                        median_oracle_rounds: stats::median(&oracles),
                        median_extra_rounds: stats::median(&extras),
                        median_virtual_time: stats::median(&vtimes),
                        median_final_primal: stats::median(&primals),
                        converged_fraction: converged as f64
                            / cfg.seeds.max(1) as f64,
                        median_dropped: stats::median(&dropped),
                        median_collective_timeouts: stats::median(&ctimeouts),
                        median_gossip_ticks: stats::median(&gticks),
                    });
                }
            }
        }
    }

    let mut w = CsvWriter::create(out_dir.join("cluster_scenarios.csv"), &[
        "machines", "collective", "scheme", "scenario", "median_rounds",
        "median_oracle_rounds", "median_extra_rounds", "median_virtual_time",
        "median_final_primal", "converged_fraction", "median_dropped",
        "median_collective_timeouts", "median_gossip_ticks",
    ])?;
    for r in &rows {
        w.row(&[
            r.machines.to_string(),
            r.collective.name().to_string(),
            r.scheme.name().to_string(),
            r.scenario.clone(),
            fnum(r.median_rounds),
            fnum(r.median_oracle_rounds),
            fnum(r.median_extra_rounds),
            fnum(r.median_virtual_time),
            fnum(r.median_final_primal),
            fnum(r.converged_fraction),
            fnum(r.median_dropped),
            fnum(r.median_collective_timeouts),
            fnum(r.median_gossip_ticks),
        ])?;
    }
    w.finish()?;
    Ok(rows)
}

/// Pretty-print the summary (CLI output).
pub fn print_summary(rows: &[ClusterScenarioRow]) {
    println!("{:<4} {:<7} {:<12} {:<8} {:>7} {:>7} {:>6} {:>9} {:>13} {:>5} {:>8}",
             "M", "coll", "scheme", "scen", "rounds", "oracle", "extra",
             "vtime", "final_primal", "conv", "dropped");
    for r in rows {
        println!("{:<4} {:<7} {:<12} {:<8} {:>7.0} {:>7.0} {:>6.0} {:>9.0} \
                  {:>13.3e} {:>5.2} {:>8.0}",
                 r.machines, r.collective.name(), r.scheme.name(), r.scenario,
                 r.median_rounds, r.median_oracle_rounds, r.median_extra_rounds,
                 r.median_virtual_time, r.median_final_primal,
                 r.converged_fraction, r.median_dropped);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miniature_matrix_reports_extra_rounds() {
        let dir = std::env::temp_dir().join("fadmm_clsc_test");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = ClusterScenarioConfig {
            nodes: 8,
            machines_list: vec![2],
            seeds: 1,
            max_iters: 120,
            schemes: vec![SchemeKind::Fixed, SchemeKind::Rb],
            loss_levels: vec![0.0, 0.10],
            collectives: CollectiveKind::ALL.to_vec(),
        };
        let rows = run(&cfg, &dir).unwrap();
        // machines × scenarios × collectives × schemes
        assert_eq!(rows.len(), 2 * 2 * 2);
        assert!(dir.join("cluster_scenarios.csv").exists());
        for r in &rows {
            assert!(r.median_rounds > 0.0, "{:?}", r);
            assert!(r.median_oracle_rounds > 0.0, "{:?}", r);
        }
        // the parity contract made measurable: tree at zero faults costs
        // exactly zero extra rounds vs the oracle fold
        for r in rows.iter().filter(|r| {
            r.scenario == "zero" && r.collective == CollectiveKind::Tree
        }) {
            assert_eq!(r.median_extra_rounds, 0.0, "{:?}/{:?}", r.scheme, r.scenario);
        }
        // the lossy cells must actually have dropped traffic
        let lossy = rows.iter().find(|r| r.scenario == "loss10").unwrap();
        assert!(lossy.median_dropped > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
