//! E-cluster — the machines × loss × collective × scheme matrix over the
//! hybrid cluster runtime.
//!
//! Every cell runs the same seeded quadratic consensus problem through
//! [`ClusterRunner`] and through the single-box [`ShardedRunner`] oracle
//! (whose leader fold is the omniscient reduction the collectives
//! replace), and reports **extra rounds vs oracle** — how many more
//! rounds to the stop criterion the tree or gossip reduction costs under
//! each loss level. By the cluster parity contracts, the `tree`
//! collective at zero faults is bit-identical to the oracle, so its
//! extra-rounds cell is exactly 0 and every non-zero entry is
//! attributable to injected faults or (for `gossip`) estimator error.

use std::collections::BTreeMap;
use std::path::Path;

use crate::cluster::{ClusterConfig, ClusterRunner, CollectiveKind};
use crate::coordinator::{ShardedConfig, ShardedRunner};
use crate::error::Result;
use crate::graph::{Graph, Topology};
use crate::net::{FaultPlan, LinkModel};
use crate::penalty::SchemeKind;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::stats;

use super::common::quad_problem_factory;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct ClusterScenarioConfig {
    /// ring size
    pub nodes: usize,
    /// machine counts to sweep
    pub machines_list: Vec<usize>,
    pub seeds: usize,
    pub max_iters: usize,
    pub schemes: Vec<SchemeKind>,
    /// Bernoulli loss levels (0.0 ⇒ the zero-fault cell)
    pub loss_levels: Vec<f64>,
    pub collectives: Vec<CollectiveKind>,
}

impl Default for ClusterScenarioConfig {
    fn default() -> Self {
        ClusterScenarioConfig {
            nodes: 24,
            machines_list: vec![2, 4],
            seeds: 3,
            max_iters: 300,
            schemes: SchemeKind::ALL.to_vec(),
            loss_levels: vec![0.0, 0.10, 0.30],
            collectives: CollectiveKind::ALL.to_vec(),
        }
    }
}

/// One (machines, scenario, collective, scheme) summary row (seed medians).
#[derive(Debug, Clone)]
pub struct ClusterScenarioRow {
    pub machines: usize,
    pub collective: CollectiveKind,
    pub scheme: SchemeKind,
    pub scenario: String,
    pub median_rounds: f64,
    pub median_oracle_rounds: f64,
    /// median over seeds of (cluster rounds − oracle rounds)
    pub median_extra_rounds: f64,
    pub median_virtual_time: f64,
    pub median_final_primal: f64,
    pub converged_fraction: f64,
    pub median_dropped: f64,
    pub median_collective_timeouts: f64,
    pub median_gossip_ticks: f64,
}

fn loss_plan(loss: f64) -> FaultPlan {
    if loss <= 0.0 {
        FaultPlan::none()
    } else {
        FaultPlan {
            link: LinkModel { base: 2, jitter: 4, loss, dup: 0.02 },
            ..FaultPlan::none()
        }
    }
}

const TOL: f64 = 1e-6;
const DIM: usize = 3;

fn scenario_graph(n: usize) -> Result<Graph> {
    Topology::Ring.build(n)
}

/// Run the full sweep, write `cluster_scenarios.csv` under `out_dir`.
pub fn run(cfg: &ClusterScenarioConfig, out_dir: &Path)
           -> Result<Vec<ClusterScenarioRow>> {
    let scenarios: Vec<(String, FaultPlan)> = cfg
        .loss_levels
        .iter()
        .map(|&l| {
            let name = if l <= 0.0 {
                "zero".to_string()
            } else {
                format!("loss{:.0}", l * 100.0)
            };
            (name, loss_plan(l))
        })
        .collect();
    run_scenarios(cfg, &scenarios, out_dir)
}

/// Replay one JSON-recorded machine-level plan across the matrix
/// (`repro cluster --plan foo.json`; ids in the plan are machine ids).
pub fn run_plan(cfg: &ClusterScenarioConfig, plan: FaultPlan, out_dir: &Path)
                -> Result<Vec<ClusterScenarioRow>> {
    run_scenarios(cfg, &[("plan".to_string(), plan)], out_dir)
}

fn run_scenarios(cfg: &ClusterScenarioConfig,
                 scenarios: &[(String, FaultPlan)], out_dir: &Path)
                 -> Result<Vec<ClusterScenarioRow>> {
    // oracle rounds per (machines, scheme, seed): the sharded runner with
    // workers = machines folds the identical shard partials omnisciently
    let mut oracle: BTreeMap<(usize, usize, u64), f64> = BTreeMap::new();
    let scheme_index =
        |s: SchemeKind| SchemeKind::ALL.iter().position(|&k| k == s).unwrap();
    for &machines in &cfg.machines_list {
        for &scheme in &cfg.schemes {
            for seed in 0..cfg.seeds as u64 {
                let report = ShardedRunner::new(
                    scenario_graph(cfg.nodes)?,
                    ShardedConfig {
                        scheme,
                        tol: TOL,
                        max_iters: cfg.max_iters,
                        seed,
                        workers: machines,
                        ..Default::default()
                    },
                )
                .run(quad_problem_factory(cfg.nodes, DIM, 1000 + seed))?;
                oracle.insert((machines, scheme_index(scheme), seed),
                              report.iterations as f64);
            }
        }
    }

    let mut rows = Vec::new();
    let mut counter_rows = Vec::new();
    // per-round series rows, scenario-cell-prefixed; populated only when
    // series recording is armed (e.g. `--series`), so the default sweep
    // output set is unchanged
    let mut series_rows: Vec<Vec<String>> = Vec::new();
    for &machines in &cfg.machines_list {
        for (scenario_name, plan) in scenarios {
            let faulty = plan.link.loss > 0.0
                || !plan.partitions.is_empty()
                || !plan.churn.is_empty();
            for &collective in &cfg.collectives {
                for &scheme in &cfg.schemes {
                    let mut rounds = Vec::with_capacity(cfg.seeds);
                    let mut extras = Vec::with_capacity(cfg.seeds);
                    let mut oracles = Vec::with_capacity(cfg.seeds);
                    let mut vtimes = Vec::with_capacity(cfg.seeds);
                    let mut primals = Vec::with_capacity(cfg.seeds);
                    let mut dropped = Vec::with_capacity(cfg.seeds);
                    let mut ctimeouts = Vec::with_capacity(cfg.seeds);
                    let mut gticks = Vec::with_capacity(cfg.seeds);
                    let mut converged = 0usize;
                    for seed in 0..cfg.seeds as u64 {
                        let runner = ClusterRunner::new(
                            scenario_graph(cfg.nodes)?,
                            ClusterConfig {
                                scheme,
                                tol: TOL,
                                max_iters: cfg.max_iters,
                                seed,
                                machines,
                                workers: 1,
                                collective,
                                max_staleness: if faulty { 1 } else { 0 },
                                silence_timeout: 16,
                                collective_timeout: 24,
                                fallback_after: 2,
                                tracing: false,
                                ..Default::default()
                            },
                            plan.clone(),
                            quad_problem_factory(cfg.nodes, DIM, 1000 + seed),
                        )?;
                        let report = runner.run();
                        let base =
                            oracle[&(machines, scheme_index(scheme), seed)];
                        rounds.push(report.iterations as f64);
                        oracles.push(base);
                        extras.push(report.iterations as f64 - base);
                        vtimes.push(report.virtual_time as f64);
                        primals.push(report
                            .recorder
                            .stats
                            .last()
                            .map(|s| s.max_primal)
                            .unwrap_or(f64::NAN));
                        dropped.push(report.counters.dropped_total() as f64);
                        ctimeouts.push(report.counters.collective_timeouts as f64);
                        gticks.push(report.counters.gossip_ticks as f64);
                        // full counter surface, one row per run, through
                        // the single NetCounters::summary_json path
                        {
                            use crate::util::json::{num, obj, s};
                            counter_rows.push(obj(vec![
                                ("machines", num(machines as f64)),
                                ("collective", s(collective.name())),
                                ("scheme", s(scheme.name())),
                                ("scenario", s(scenario_name)),
                                ("seed", num(seed as f64)),
                                ("counters", report.counters.summary_json()),
                            ]));
                        }
                        for sr in &report.series {
                            let mut row = vec![machines.to_string(),
                                               collective.name().to_string(),
                                               scheme.name().to_string(),
                                               scenario_name.clone(),
                                               seed.to_string()];
                            row.extend(crate::obs::series_csv_row(sr));
                            series_rows.push(row);
                        }
                        if report.converged {
                            converged += 1;
                        }
                    }
                    rows.push(ClusterScenarioRow {
                        machines,
                        collective,
                        scheme,
                        scenario: scenario_name.clone(),
                        median_rounds: stats::median(&rounds),
                        median_oracle_rounds: stats::median(&oracles),
                        median_extra_rounds: stats::median(&extras),
                        median_virtual_time: stats::median(&vtimes),
                        median_final_primal: stats::median(&primals),
                        converged_fraction: converged as f64
                            / cfg.seeds.max(1) as f64,
                        median_dropped: stats::median(&dropped),
                        median_collective_timeouts: stats::median(&ctimeouts),
                        median_gossip_ticks: stats::median(&gticks),
                    });
                }
            }
        }
    }

    let mut w = CsvWriter::create(out_dir.join("cluster_scenarios.csv"), &[
        "machines", "collective", "scheme", "scenario", "median_rounds",
        "median_oracle_rounds", "median_extra_rounds", "median_virtual_time",
        "median_final_primal", "converged_fraction", "median_dropped",
        "median_collective_timeouts", "median_gossip_ticks",
    ])?;
    for r in &rows {
        w.row(&[
            r.machines.to_string(),
            r.collective.name().to_string(),
            r.scheme.name().to_string(),
            r.scenario.clone(),
            fnum(r.median_rounds),
            fnum(r.median_oracle_rounds),
            fnum(r.median_extra_rounds),
            fnum(r.median_virtual_time),
            fnum(r.median_final_primal),
            fnum(r.converged_fraction),
            fnum(r.median_dropped),
            fnum(r.median_collective_timeouts),
            fnum(r.median_gossip_ticks),
        ])?;
    }
    w.finish()?;
    let counters_path = out_dir.join("cluster_counters.json");
    std::fs::write(&counters_path,
                   crate::util::json::arr(counter_rows).to_string())
        .map_err(|e| crate::error::Error::io(
            format!("writing {}", counters_path.display()), e,
        ))?;
    if !series_rows.is_empty() {
        let mut hdr = vec!["machines", "collective", "scheme", "scenario",
                           "seed"];
        hdr.extend(crate::obs::SERIES_CSV_HEADER);
        let mut w = CsvWriter::create(out_dir.join("cluster_series.csv"), &hdr)?;
        for r in &series_rows {
            w.row(r)?;
        }
        w.finish()?;
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// D-PPCA through the cluster runtime (ROADMAP open item): a ring of 4
// machines under 10% loss, scored by the Fig. 2-style subspace angle via
// the unified app-metric hook, against the single-box ShardedRunner
// oracle running the identical problem.

/// One D-PPCA cluster cell vs the single-box oracle.
#[derive(Debug, Clone)]
pub struct DppcaClusterRow {
    pub machines: usize,
    pub loss: f64,
    pub cluster_rounds: usize,
    pub oracle_rounds: usize,
    /// final max-over-nodes subspace angle (degrees) under the cluster
    pub cluster_final_angle: f64,
    pub oracle_final_angle: f64,
    /// first recorded angle (sanity: the curve must come down from here)
    pub cluster_initial_angle: f64,
    pub dropped: u64,
}

/// [`crate::dppca::DppcaSolver`] wrapper asserting cross-thread mobility
/// for the cluster machine pools.
///
/// Soundness: `DppcaSolver` is `!Send` only because it holds its backend
/// as `Rc<RefCell<dyn Backend>>`. The factory below creates a **fresh,
/// solver-private** `NativeBackend` per call — the `Rc` never escapes the
/// wrapped solver, so moving the whole solver between the pool's scoped
/// threads transfers the only reference and no `Rc` count is ever
/// touched concurrently. The XLA backend (whose PJRT handles are the
/// real reason for `!Send`) must never travel through this wrapper.
struct SendDppca(crate::dppca::DppcaSolver);

// Safety: see type docs — the wrapped solver owns its backend exclusively.
unsafe impl Send for SendDppca {}

impl crate::consensus::LocalSolver for SendDppca {
    fn dim(&self) -> usize {
        self.0.dim()
    }

    fn initial_param(&mut self, rng: &mut crate::util::rng::Pcg) -> Vec<f64> {
        self.0.initial_param(rng)
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        self.0.objective(theta)
    }

    fn objective_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        self.0.objective_batch(thetas)
    }

    fn objective_batch_into(&mut self, thetas: &[Vec<f64>], out: &mut Vec<f64>) {
        self.0.objective_batch_into(thetas, out)
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        self.0.solve(theta, lambda, eta_sum, eta_wsum)
    }

    fn solve_into(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        self.0.solve_into(theta, lambda, eta_sum, eta_wsum, out)
    }
}

const DPPCA_D: usize = 6;
const DPPCA_M: usize = 2;

fn dppca_factory(blocks: std::sync::Arc<Vec<crate::linalg::Mat>>)
                 -> crate::coordinator::SolverFactory<SendDppca> {
    std::sync::Arc::new(move |i| {
        let backend = crate::runtime::shared(crate::runtime::NativeBackend::new());
        SendDppca(
            crate::dppca::DppcaSolver::from_block(blocks[i].clone(), DPPCA_M,
                                                  backend)
                .expect("dppca block"),
        )
    })
}

/// Run the D-PPCA cluster cell (`repro cluster --dppca`): 4 machines on a
/// 4-node ring, 10% loss, tree collective, subspace-angle hook — vs the
/// single-box `ShardedRunner` on the identical seeded problem. Writes
/// `cluster_dppca.csv` under `out_dir`.
pub fn run_dppca(max_iters: usize, out_dir: &Path) -> Result<DppcaClusterRow> {
    use crate::data::{even_split, SubspaceSpec};
    use crate::experiments::common::max_angle_vs_reference;
    use crate::util::rng::Pcg;

    let machines = 4usize;
    let loss = 0.10f64;
    let spec = SubspaceSpec { d: DPPCA_D, m: DPPCA_M, n: 48, noise_var: 0.05,
                              random_mean: false };
    let data = spec.generate(&mut Pcg::seed(4));
    let part = even_split(48, machines);
    let blocks: Vec<crate::linalg::Mat> = part
        .ranges
        .iter()
        .map(|&(lo, hi)| data.x.col_slice(lo, hi))
        .collect();
    let blocks = std::sync::Arc::new(blocks);

    let w_oracle = data.w_true.clone();
    let oracle = ShardedRunner::new(
        Topology::Ring.build(machines)?,
        ShardedConfig { scheme: SchemeKind::Ap, tol: 1e-5, max_iters, seed: 2,
                        workers: machines, ..Default::default() },
    )
    .run_hooked(
        dppca_factory(blocks.clone()),
        move |_t: usize, thetas: &[Vec<f64>], _live: &[bool]| {
            max_angle_vs_reference(thetas, DPPCA_D, DPPCA_M, &w_oracle)
        },
    )?;

    let w_cluster = data.w_true.clone();
    let cluster = ClusterRunner::new(
        Topology::Ring.build(machines)?,
        ClusterConfig {
            scheme: SchemeKind::Ap,
            tol: 1e-5,
            max_iters,
            seed: 2,
            machines,
            workers: 1,
            collective: CollectiveKind::Tree,
            max_staleness: 1,
            silence_timeout: 16,
            collective_timeout: 24,
            fallback_after: 2,
            tracing: false,
            ..Default::default()
        },
        loss_plan(loss),
        dppca_factory(blocks),
    )?
    .with_app_metric(move |_t: usize, thetas: &[Vec<f64>], _live: &[bool]| {
        max_angle_vs_reference(thetas, DPPCA_D, DPPCA_M, &w_cluster)
    })
    .run();

    let curve = cluster.recorder.error_curve();
    let row = DppcaClusterRow {
        machines,
        loss,
        cluster_rounds: cluster.iterations,
        oracle_rounds: oracle.iterations,
        cluster_final_angle: cluster.recorder.final_error(),
        oracle_final_angle: oracle.recorder.final_error(),
        cluster_initial_angle: curve.first().copied().unwrap_or(f64::NAN),
        dropped: cluster.counters.dropped_total(),
    };

    let mut w = CsvWriter::create(out_dir.join("cluster_dppca.csv"), &[
        "machines", "loss", "cluster_rounds", "oracle_rounds",
        "cluster_final_angle", "oracle_final_angle", "cluster_initial_angle",
        "dropped",
    ])?;
    w.row(&[
        row.machines.to_string(),
        fnum(row.loss),
        row.cluster_rounds.to_string(),
        row.oracle_rounds.to_string(),
        fnum(row.cluster_final_angle),
        fnum(row.oracle_final_angle),
        fnum(row.cluster_initial_angle),
        fnum(row.dropped as f64),
    ])?;
    w.finish()?;
    Ok(row)
}

/// Pretty-print the D-PPCA cell.
pub fn print_dppca(row: &DppcaClusterRow) {
    println!("dppca cluster: {} machines @ {:.0}% loss — rounds {} (oracle {}), \
              angle {:.2}° from {:.2}° (oracle {:.2}°), dropped {}",
             row.machines, row.loss * 100.0, row.cluster_rounds,
             row.oracle_rounds, row.cluster_final_angle,
             row.cluster_initial_angle, row.oracle_final_angle, row.dropped);
}

/// Pretty-print the summary (CLI output).
pub fn print_summary(rows: &[ClusterScenarioRow]) {
    println!("{:<4} {:<7} {:<12} {:<8} {:>7} {:>7} {:>6} {:>9} {:>13} {:>5} {:>8}",
             "M", "coll", "scheme", "scen", "rounds", "oracle", "extra",
             "vtime", "final_primal", "conv", "dropped");
    for r in rows {
        println!("{:<4} {:<7} {:<12} {:<8} {:>7.0} {:>7.0} {:>6.0} {:>9.0} \
                  {:>13.3e} {:>5.2} {:>8.0}",
                 r.machines, r.collective.name(), r.scheme.name(), r.scenario,
                 r.median_rounds, r.median_oracle_rounds, r.median_extra_rounds,
                 r.median_virtual_time, r.median_final_primal,
                 r.converged_fraction, r.median_dropped);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miniature_matrix_reports_extra_rounds() {
        let dir = std::env::temp_dir().join("fadmm_clsc_test");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = ClusterScenarioConfig {
            nodes: 8,
            machines_list: vec![2],
            seeds: 1,
            max_iters: 120,
            schemes: vec![SchemeKind::Fixed, SchemeKind::Rb],
            loss_levels: vec![0.0, 0.10],
            collectives: CollectiveKind::ALL.to_vec(),
        };
        let rows = run(&cfg, &dir).unwrap();
        // machines × scenarios × collectives × schemes
        assert_eq!(rows.len(), 2 * 2 * 2);
        assert!(dir.join("cluster_scenarios.csv").exists());
        assert!(dir.join("cluster_counters.json").exists());
        for r in &rows {
            assert!(r.median_rounds > 0.0, "{:?}", r);
            assert!(r.median_oracle_rounds > 0.0, "{:?}", r);
        }
        // the parity contract made measurable: tree at zero faults costs
        // exactly zero extra rounds vs the oracle fold
        for r in rows.iter().filter(|r| {
            r.scenario == "zero" && r.collective == CollectiveKind::Tree
        }) {
            assert_eq!(r.median_extra_rounds, 0.0, "{:?}/{:?}", r.scheme, r.scenario);
        }
        // the lossy cells must actually have dropped traffic
        let lossy = rows.iter().find(|r| r.scenario == "loss10").unwrap();
        assert!(lossy.median_dropped > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dppca_cluster_cell_recovers_subspace_under_loss() {
        // the ROADMAP item: D-PPCA through ClusterRunner via the unified
        // app-metric hook — ring of 4 machines, 10% loss, Fig. 2-style
        // subspace error smoke-tested against the single-box oracle
        let dir = std::env::temp_dir().join("fadmm_cldppca_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let row = run_dppca(150, &dir).unwrap();
        assert_eq!(row.machines, 4);
        assert!(row.dropped > 0, "the loss model must have bitten");
        assert!(row.cluster_final_angle.is_finite());
        assert!(row.oracle_final_angle.is_finite());
        assert!(row.cluster_final_angle < row.cluster_initial_angle,
                "subspace angle must improve under loss: {} → {}",
                row.cluster_initial_angle, row.cluster_final_angle);
        // the cluster under 10% loss tracks the clean single-box curve to
        // within a loose smoke bound (both should be far below random)
        assert!(row.cluster_final_angle < 25.0,
                "cluster angle {}°", row.cluster_final_angle);
        assert!(dir.join("cluster_dppca.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
