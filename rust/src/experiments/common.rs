//! Shared experiment plumbing: backend selection, one D-PPCA consensus
//! run, and the subspace-angle observer.

use crate::consensus::{Engine, EngineConfig};
use crate::dppca::{DppcaSolver, InitStrategy, PpcaParams, UpdateMode};
use crate::error::Result;
use crate::graph::Graph;
use crate::linalg::{max_principal_angle_deg, Mat};
use crate::metrics::Recorder;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::runtime::{shared, NativeBackend, SharedBackend};
#[cfg(feature = "xla")]
use crate::runtime::XlaBackend;

/// Which compute backend executes the node updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// AOT-lowered HLO artifacts through PJRT (the production path).
    Xla,
    /// Pure-Rust oracle (identical numbers; no artifacts needed).
    Native,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<BackendChoice> {
        match s {
            "xla" => Ok(BackendChoice::Xla),
            "native" => Ok(BackendChoice::Native),
            _ => Err(crate::Error::Config(format!("unknown backend '{s}'"))),
        }
    }

    /// Instantiate (XLA backends warm their executable cache lazily).
    pub fn build(self) -> Result<SharedBackend> {
        match self {
            #[cfg(feature = "xla")]
            BackendChoice::Xla => Ok(shared(XlaBackend::from_default_dir()?)),
            #[cfg(not(feature = "xla"))]
            BackendChoice::Xla => Err(crate::Error::Config(
                "xla backend unavailable in this build: rebuild with \
                 `--features xla` (and vendor the xla crate)".into(),
            )),
            BackendChoice::Native => Ok(shared(NativeBackend::new())),
        }
    }
}

/// One distributed PPCA problem instance.
pub struct DppcaSpec<'a> {
    /// per-node data blocks (D × N_i, unpadded)
    pub blocks: Vec<Mat>,
    /// padded per-node sample budget (must match an artifact shape)
    pub n_padded: usize,
    /// latent dimension
    pub m: usize,
    pub graph: Graph,
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    pub seed: u64,
    pub max_iters: usize,
    /// convergence tolerance on the relative objective change (paper: 1e-3)
    pub tol: f64,
    pub mode: UpdateMode,
    pub init: InitStrategy,
    /// ground-truth basis for the subspace-angle observer (D × M)
    pub reference: Option<&'a Mat>,
}

impl<'a> DppcaSpec<'a> {
    /// Defaults matching the paper's experimental setting.
    pub fn new(blocks: Vec<Mat>, n_padded: usize, m: usize, graph: Graph,
               scheme: SchemeKind) -> DppcaSpec<'a> {
        DppcaSpec {
            blocks,
            n_padded,
            m,
            graph,
            scheme,
            params: SchemeParams::default(),
            seed: 0,
            max_iters: 600,
            tol: 1e-3,
            mode: UpdateMode::CachedMoments,
            init: InitStrategy::Random,
            reference: None,
        }
    }
}

/// Result of one run.
#[derive(Debug)]
pub struct DppcaRunResult {
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
    /// final per-node parameters
    pub params: Vec<PpcaParams>,
    /// final subspace-angle error vs the reference (NaN without reference)
    pub final_angle: f64,
}

/// Max-over-nodes subspace angle between each node's W and `reference` —
/// the paper's plotted error metric.
pub fn max_angle_vs_reference(thetas: &[Vec<f64>], d: usize, m: usize,
                              reference: &Mat) -> f64 {
    thetas
        .iter()
        .map(|flat| {
            let p = PpcaParams::unflatten(d, m, flat);
            max_principal_angle_deg(&p.w, reference).unwrap_or(90.0)
        })
        .fold(0.0, f64::max)
}

/// Run one distributed D-PPCA instance on the chosen backend.
pub fn run_dppca(spec: &DppcaSpec<'_>, backend: SharedBackend) -> Result<DppcaRunResult> {
    let d = spec.blocks[0].rows();
    let m = spec.m;
    assert_eq!(spec.blocks.len(), spec.graph.len(), "one block per node");

    let mut solvers = Vec::with_capacity(spec.blocks.len());
    for block in &spec.blocks {
        let solver = DppcaSolver::from_padded_block(block, spec.n_padded, m,
                                                    backend.clone())?
            .with_init(spec.init)
            .with_mode(spec.mode);
        solvers.push(solver);
    }
    let cfg = EngineConfig {
        scheme: spec.scheme,
        params: spec.params,
        tol: spec.tol,
        max_iters: spec.max_iters,
        seed: spec.seed,
        ..Default::default()
    };
    let mut engine = Engine::new(spec.graph.clone(), solvers, cfg);
    let reference = spec.reference;
    let report = match reference {
        Some(basis) => engine.run_with(|_t, thetas| {
            max_angle_vs_reference(thetas, d, m, basis)
        }),
        None => engine.run(),
    };
    let params: Vec<PpcaParams> = report
        .thetas
        .iter()
        .map(|flat| PpcaParams::unflatten(d, m, flat))
        .collect();
    Ok(DppcaRunResult {
        final_angle: report.recorder.final_error(),
        iterations: report.iterations,
        converged: report.converged,
        recorder: report.recorder,
        params,
    })
}

/// Paper scheme lineup for the figures.
pub fn paper_schemes() -> &'static [SchemeKind] {
    &SchemeKind::PAPER
}

/// A seeded quadratic consensus problem (one random SPD node objective
/// per graph node) — the cheap workload behind the net-scenario sweep and
/// benches, where the subject under test is the runtime, not the model.
pub fn quad_problem(n: usize, dim: usize, seed: u64)
                    -> Vec<crate::consensus::solvers::QuadraticNode> {
    let mut rng = crate::util::rng::Pcg::seed(seed);
    (0..n)
        .map(|_| crate::consensus::solvers::QuadraticNode::random(dim, &mut rng))
        .collect()
}

/// [`quad_problem`] behind a cloneable [`crate::coordinator::SolverFactory`]:
/// the node matrices are materialized once and every factory call rebuilds
/// the same solver, so the sharded oracle and the cluster runtime construct
/// *identical* per-node problems (the extra-rounds-vs-oracle comparisons
/// and the bit-parity tests all depend on this).
pub fn quad_problem_factory(n: usize, dim: usize, seed: u64)
    -> crate::coordinator::SolverFactory<crate::consensus::solvers::QuadraticNode> {
    use crate::consensus::solvers::QuadraticNode;
    let nodes: Vec<(crate::linalg::Mat, Vec<f64>)> = quad_problem(n, dim, seed)
        .into_iter()
        .map(|q| (q.p, q.q))
        .collect();
    let nodes = std::sync::Arc::new(nodes);
    std::sync::Arc::new(move |i| {
        let (p, q) = nodes[i].clone();
        QuadraticNode::new(p, q)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{even_split, SubspaceSpec};
    use crate::graph::Topology;
    use crate::util::rng::Pcg;

    #[test]
    fn dppca_consensus_recovers_subspace_native() {
        // miniature fig2: 4 nodes, complete graph, native backend
        let spec_data = SubspaceSpec { d: 8, m: 2, n: 60, noise_var: 0.1, random_mean: false };
        let data = spec_data.generate(&mut Pcg::seed(1));
        let part = even_split(60, 4);
        let blocks: Vec<Mat> = part
            .ranges
            .iter()
            .map(|&(lo, hi)| data.x.col_slice(lo, hi))
            .collect();
        let mut spec = DppcaSpec::new(blocks, 16, 2,
                                      Topology::Complete.build(4).unwrap(),
                                      SchemeKind::Ap);
        spec.reference = Some(&data.w_true);
        spec.max_iters = 400;
        spec.tol = 1e-6;
        let backend = BackendChoice::Native.build().unwrap();
        let result = run_dppca(&spec, backend).unwrap();
        assert!(result.final_angle < 10.0, "angle {}", result.final_angle);
        assert!(result.params.iter().all(|p| p.a > 0.0));
        // error decreased over the run
        let curve = result.recorder.error_curve();
        assert!(curve.last().unwrap() < &curve[0]);
    }
}
