//! E-net — fault-scenario sweep over the simulated-network runtime.
//!
//! The "as many scenarios as you can imagine" axis of the ROADMAP: a
//! loss × latency × churn matrix, each cell running every requested
//! penalty scheme on the same seeded quadratic consensus problem through
//! [`AsyncRunner`]. Per (scenario, scheme) the sweep reports seed-median
//! rounds, virtual time, final primal residual, convergence fraction and
//! the fault-load counters — so the cost of unreliability is measurable
//! per scheme, not anecdotal. The zero-fault `baseline` cell doubles as a
//! sanity anchor: it is bit-identical to the sequential engine by the
//! parity tests, so every other cell's delta is attributable to the
//! injected faults alone. The `stale3` cell sits deliberately past the
//! staleness stability boundary (see the [`crate::net`] module docs) and
//! is expected to *diverge* — its growing `final_primal` is the measured
//! counterexample justifying the `max_staleness ≤ 1` setting everywhere
//! else.

use std::path::Path;

use crate::error::Result;
use crate::graph::Graph;
use crate::net::{AsyncRunner, ChurnEvent, FaultPlan, LinkModel, NetConfig,
                 Partition};
use crate::penalty::SchemeKind;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::stats;

use super::common::quad_problem;

/// One named fault scenario of the sweep matrix.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub plan: FaultPlan,
    /// staleness budget in rounds (0 = lock-step)
    pub max_staleness: u64,
    /// silent-neighbour fallback timeout in ticks (0 = pure blocking)
    pub silence_timeout: u64,
    /// lag-aware λ damping (the `stale3_damped` comparison cell)
    pub lag_damping: bool,
    /// skip-λ-on-fallback (the `stale3_skip` comparison cell)
    pub skip_lambda: bool,
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct NetScenarioConfig {
    /// ring size (the churn scenario adds one bridging joiner node)
    pub nodes: usize,
    pub seeds: usize,
    pub max_iters: usize,
    pub schemes: Vec<SchemeKind>,
}

impl Default for NetScenarioConfig {
    fn default() -> Self {
        NetScenarioConfig {
            nodes: 12,
            seeds: 5,
            max_iters: 400,
            schemes: SchemeKind::ALL.to_vec(),
        }
    }
}

/// One (scenario, scheme) summary row (seed medians).
#[derive(Debug, Clone)]
pub struct NetScenarioRow {
    pub scenario: String,
    pub scheme: SchemeKind,
    pub median_rounds: f64,
    pub median_virtual_time: f64,
    pub median_final_primal: f64,
    pub converged_fraction: f64,
    pub median_dropped: f64,
    pub median_stale_reads: f64,
}

/// The scenario matrix for an n-node ring (loss × latency × churn, plus a
/// transient partition). The churn scenario runs on n+1 nodes: the extra
/// node bridges two ring antipodes, joins mid-run, and a ring node leaves
/// later — the live subgraph stays connected throughout.
pub fn scenario_matrix(n: usize) -> Vec<Scenario> {
    let lossy = |loss: f64| LinkModel { base: 2, jitter: 4, loss, dup: 0.02 };
    vec![
        Scenario {
            name: "baseline",
            plan: FaultPlan::none(),
            max_staleness: 0,
            silence_timeout: 64,
            lag_damping: false,
            skip_lambda: false,
        },
        Scenario {
            name: "latency",
            plan: FaultPlan {
                link: LinkModel { base: 3, jitter: 7, loss: 0.0, dup: 0.0 },
                ..FaultPlan::none()
            },
            max_staleness: 1,
            silence_timeout: 32,
            lag_damping: false,
            skip_lambda: false,
        },
        Scenario {
            name: "loss10",
            plan: FaultPlan { link: lossy(0.10), ..FaultPlan::none() },
            max_staleness: 1,
            silence_timeout: 16,
            lag_damping: false,
            skip_lambda: false,
        },
        Scenario {
            name: "loss30",
            plan: FaultPlan { link: lossy(0.30), ..FaultPlan::none() },
            max_staleness: 1,
            silence_timeout: 16,
            lag_damping: false,
            skip_lambda: false,
        },
        // deliberately past the stability boundary: three rounds of
        // systematic read lag destabilize the dual accumulation (the
        // generation mismatch in λ updates random-walks with positive
        // feedback), so final_primal grows instead of vanishing — the
        // sweep keeps the cell as the measured counterexample for why
        // the other scenarios run at max_staleness ≤ 1
        Scenario {
            name: "stale3",
            plan: FaultPlan { link: lossy(0.10), ..FaultPlan::none() },
            max_staleness: 3,
            silence_timeout: 16,
            lag_damping: false,
            skip_lambda: false,
        },
        // the same over-budget cell with lag-aware λ damping: each stale
        // dual step is scaled by 1/(1+lag), so the comparison against
        // `stale3` measures whether damping moves the staleness ≥ 2
        // divergence boundary out (the ROADMAP open item)
        Scenario {
            name: "stale3_damped",
            plan: FaultPlan { link: lossy(0.10), ..FaultPlan::none() },
            max_staleness: 3,
            silence_timeout: 16,
            lag_damping: true,
            skip_lambda: false,
        },
        // ... and with the *complementary* policy: λ increments from
        // forced fallback reads (lag past the budget) are skipped
        // outright while within-budget stale steps stay untouched — the
        // `stale3` → `stale3_damped` → `stale3_skip` triple measures
        // shrink-vs-drop on the same over-budget cell
        Scenario {
            name: "stale3_skip",
            plan: FaultPlan { link: lossy(0.10), ..FaultPlan::none() },
            max_staleness: 3,
            silence_timeout: 16,
            lag_damping: false,
            skip_lambda: true,
        },
        Scenario {
            name: "partition",
            plan: FaultPlan {
                link: LinkModel { base: 1, jitter: 2, loss: 0.0, dup: 0.0 },
                partitions: vec![Partition {
                    start: 50,
                    end: 250,
                    group: (0..n / 2).collect(),
                }],
                ..FaultPlan::none()
            },
            max_staleness: 1,
            silence_timeout: 8,
            lag_damping: false,
            skip_lambda: false,
        },
        Scenario {
            name: "churn",
            plan: FaultPlan {
                link: lossy(0.10),
                partitions: vec![],
                churn: vec![
                    ChurnEvent::Join { at: 200, node: n },
                    ChurnEvent::Leave { at: 600, node: n / 4 },
                ],
                initially_dormant: vec![n],
            },
            max_staleness: 1,
            silence_timeout: 16,
            lag_damping: false,
            skip_lambda: false,
        },
    ]
}

/// A single-scenario sweep replaying a JSON-recorded [`FaultPlan`]
/// (`repro net --plan foo.json`). Staleness/timeout knobs take the lossy
/// defaults; damping stays off.
pub fn plan_scenario(plan: FaultPlan) -> Scenario {
    Scenario {
        name: "plan",
        plan,
        max_staleness: 1,
        silence_timeout: 16,
        lag_damping: false,
        skip_lambda: false,
    }
}

/// The communication graph for a scenario: a ring, plus — for churn — the
/// bridging joiner node n connected to antipodes 0 and n/2.
fn scenario_graph(n: usize, churn: bool) -> Result<Graph> {
    let mut edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    if churn {
        edges.push((n, 0));
        edges.push((n, n / 2));
        Graph::new(n + 1, &edges)
    } else {
        Graph::new(n, &edges)
    }
}

/// Run the full sweep, write `net_scenarios.csv` under `out_dir`.
pub fn run(cfg: &NetScenarioConfig, out_dir: &Path) -> Result<Vec<NetScenarioRow>> {
    run_scenarios(cfg, scenario_matrix(cfg.nodes), out_dir)
}

/// Replay one JSON-recorded plan as a single-scenario sweep
/// (`repro net --plan foo.json`). Churn events on node id `nodes` drive
/// the bridging joiner node the churn graph adds.
pub fn run_plan(cfg: &NetScenarioConfig, plan: FaultPlan, out_dir: &Path)
                -> Result<Vec<NetScenarioRow>> {
    run_scenarios(cfg, vec![plan_scenario(plan)], out_dir)
}

fn run_scenarios(cfg: &NetScenarioConfig, scenarios: Vec<Scenario>,
                 out_dir: &Path) -> Result<Vec<NetScenarioRow>> {
    use crate::util::json::{arr, num, obj, s};
    let mut rows = Vec::new();
    let mut counter_rows = Vec::new();
    // per-round series rows, scenario-cell-prefixed; populated only when
    // series recording is armed (e.g. `--series`), so the default sweep
    // output set is unchanged
    let mut series_rows: Vec<Vec<String>> = Vec::new();
    for scenario in scenarios {
        let churn = !scenario.plan.churn.is_empty();
        for &scheme in &cfg.schemes {
            let mut rounds = Vec::with_capacity(cfg.seeds);
            let mut vtimes = Vec::with_capacity(cfg.seeds);
            let mut primals = Vec::with_capacity(cfg.seeds);
            let mut dropped = Vec::with_capacity(cfg.seeds);
            let mut stale = Vec::with_capacity(cfg.seeds);
            let mut converged = 0usize;
            for seed in 0..cfg.seeds as u64 {
                let graph = scenario_graph(cfg.nodes, churn)?;
                let solvers = quad_problem(graph.len(), 3, 1000 + seed);
                let runner = AsyncRunner::new(graph, solvers, NetConfig {
                    scheme,
                    tol: 1e-6,
                    max_iters: cfg.max_iters,
                    seed,
                    max_staleness: scenario.max_staleness,
                    silence_timeout: scenario.silence_timeout,
                    lag_damping: scenario.lag_damping,
                    skip_lambda_on_fallback: scenario.skip_lambda,
                    tracing: false,
                    ..Default::default()
                }, scenario.plan.clone());
                let report = runner.run();
                rounds.push(report.iterations as f64);
                vtimes.push(report.virtual_time as f64);
                primals.push(report
                    .recorder
                    .stats
                    .last()
                    .map(|s| s.max_primal)
                    .unwrap_or(f64::NAN));
                dropped.push(report.counters.dropped_total() as f64);
                stale.push(report.counters.stale_reads as f64);
                // the full counter surface, one row per run, through the
                // single NetCounters::summary_json path
                counter_rows.push(obj(vec![
                    ("scenario", s(scenario.name)),
                    ("scheme", s(scheme.name())),
                    ("seed", num(seed as f64)),
                    ("counters", report.counters.summary_json()),
                ]));
                for sr in &report.series {
                    let mut row = vec![scenario.name.to_string(),
                                       scheme.name().to_string(),
                                       seed.to_string()];
                    row.extend(crate::obs::series_csv_row(sr));
                    series_rows.push(row);
                }
                if report.converged {
                    converged += 1;
                }
            }
            rows.push(NetScenarioRow {
                scenario: scenario.name.to_string(),
                scheme,
                median_rounds: stats::median(&rounds),
                median_virtual_time: stats::median(&vtimes),
                median_final_primal: stats::median(&primals),
                converged_fraction: converged as f64 / cfg.seeds.max(1) as f64,
                median_dropped: stats::median(&dropped),
                median_stale_reads: stats::median(&stale),
            });
        }
    }

    let mut w = CsvWriter::create(out_dir.join("net_scenarios.csv"), &[
        "scenario", "scheme", "median_rounds", "median_virtual_time",
        "median_final_primal", "converged_fraction", "median_dropped",
        "median_stale_reads",
    ])?;
    for r in &rows {
        w.row(&[
            r.scenario.clone(),
            r.scheme.name().to_string(),
            fnum(r.median_rounds),
            fnum(r.median_virtual_time),
            fnum(r.median_final_primal),
            fnum(r.converged_fraction),
            fnum(r.median_dropped),
            fnum(r.median_stale_reads),
        ])?;
    }
    w.finish()?;
    let counters_path = out_dir.join("net_counters.json");
    std::fs::write(&counters_path, arr(counter_rows).to_string()).map_err(
        |e| crate::error::Error::io(
            format!("writing {}", counters_path.display()), e,
        ),
    )?;
    if !series_rows.is_empty() {
        let mut hdr = vec!["scenario", "scheme", "seed"];
        hdr.extend(crate::obs::SERIES_CSV_HEADER);
        let mut w = CsvWriter::create(out_dir.join("net_series.csv"), &hdr)?;
        for r in &series_rows {
            w.row(r)?;
        }
        w.finish()?;
    }
    Ok(rows)
}

/// Pretty-print the summary (CLI output).
pub fn print_summary(rows: &[NetScenarioRow]) {
    println!("{:<12} {:<12} {:>8} {:>10} {:>14} {:>6} {:>9} {:>7}",
             "scenario", "scheme", "rounds", "vtime", "final_primal", "conv",
             "dropped", "stale");
    for r in rows {
        println!("{:<12} {:<12} {:>8.0} {:>10.0} {:>14.3e} {:>6.2} {:>9.0} {:>7.0}",
                 r.scenario, r.scheme.name(), r.median_rounds,
                 r.median_virtual_time, r.median_final_primal,
                 r.converged_fraction, r.median_dropped, r.median_stale_reads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miniature_matrix_produces_all_rows() {
        let dir = std::env::temp_dir().join("fadmm_netsc_test");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = NetScenarioConfig {
            nodes: 6,
            seeds: 1,
            max_iters: 60,
            schemes: vec![SchemeKind::Fixed, SchemeKind::Nap],
        };
        let rows = run(&cfg, &dir).unwrap();
        assert_eq!(rows.len(), scenario_matrix(6).len() * 2);
        assert!(dir.join("net_scenarios.csv").exists());
        // the uniform counter surface: one JSON row per run, parseable
        let raw = std::fs::read_to_string(dir.join("net_counters.json")).unwrap();
        let v = crate::util::json::Json::parse(&raw).unwrap();
        let rows_json = v.as_arr().unwrap();
        assert_eq!(rows_json.len(), rows.len()); // seeds == 1
        assert!(rows_json[0].get("counters").and_then(|c| c.get("sent")).is_some());
        for r in &rows {
            assert!(r.median_rounds > 0.0, "{}/{:?}", r.scenario, r.scheme);
            // the stale3 cells are the scripted over-budget demonstration;
            // their residuals may be astronomically large (though still
            // finite at this tiny budget), so only the stable cells get
            // the finiteness bar — the damped variant's improvement is
            // measured by the CSV comparison, not asserted here
            if !r.scenario.starts_with("stale3") {
                assert!(r.median_final_primal.is_finite(),
                        "{}/{:?}", r.scenario, r.scheme);
            }
        }
        // the baseline cell sees no faults; the lossy cells must
        let base = rows.iter().find(|r| r.scenario == "baseline").unwrap();
        assert_eq!(base.median_dropped, 0.0);
        let lossy = rows.iter().find(|r| r.scenario == "loss30").unwrap();
        assert!(lossy.median_dropped > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
