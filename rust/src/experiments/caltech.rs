//! E3/E5 — paper Fig. 3 & Fig. 5 (+ Fig. 4 description): distributed
//! affine SfM on the five turntable objects.
//!
//! Five cameras on a complete or ring network; per-frame-centred,
//! transposed measurement matrices (see [`crate::sfm`]); error = max
//! subspace angle of any camera's W against the centralized SVD
//! structure. Three settings, matching the paper's figure rows:
//! (ring, t_max = 50), (complete, t_max = 50), (complete, t_max = 5).

use std::path::Path;

use super::common::{paper_schemes, run_dppca, BackendChoice, DppcaSpec};
use crate::data::{turntable_objects, TurntableObject};
use crate::error::Result;
use crate::graph::Topology;
use crate::dppca::InitStrategy;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::sfm;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::stats;

pub const CAMERAS: usize = 5;

/// The three experimental settings of Fig. 3 / Fig. 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Setting {
    pub topo: Topology,
    pub t_max: usize,
}

pub const SETTINGS: [Setting; 3] = [
    Setting { topo: Topology::Ring, t_max: 50 },
    Setting { topo: Topology::Complete, t_max: 50 },
    Setting { topo: Topology::Complete, t_max: 5 },
];

fn setting_name(s: Setting) -> String {
    format!("{}_tmax{}", s.topo.name(), s.t_max)
}

/// Summary row per (object, setting, scheme).
#[derive(Debug, Clone)]
pub struct CaltechRow {
    pub object: String,
    pub setting: String,
    pub scheme: SchemeKind,
    pub median_iterations: f64,
    pub median_final_angle: f64,
    pub curve: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct CaltechConfig {
    pub seeds: usize,
    pub backend: BackendChoice,
    pub max_iters: usize,
    pub schemes: Vec<SchemeKind>,
    /// restrict to these object names (empty = all five)
    pub objects: Vec<String>,
    pub data_seed: u64,
}

impl Default for CaltechConfig {
    fn default() -> Self {
        CaltechConfig {
            seeds: 5,
            backend: BackendChoice::Native,
            max_iters: 400,
            schemes: paper_schemes().to_vec(),
            objects: Vec::new(),
            data_seed: 0,
        }
    }
}

/// Fig. 4 substitute: per-object dataset description + SVD baseline quality.
pub fn describe(out_dir: &Path, data_seed: u64) -> Result<()> {
    let objects = turntable_objects(data_seed);
    let mut w = CsvWriter::create(out_dir.join("caltech_objects.csv"),
                                  &["object", "points", "frames",
                                    "svd_rank3_residual", "sigma4_over_sigma3"])?;
    for o in &objects {
        let (_, err) = sfm::svd_structure(&o.measurements)?;
        let centred = sfm::center_rows(&o.measurements);
        let svd = crate::linalg::Svd::new(&centred)?;
        w.row(&[o.name.clone(), o.structure.rows().to_string(),
                o.frames.to_string(), fnum(err), fnum(svd.s[3] / svd.s[2])])?;
    }
    w.finish()
}

/// Run one object × setting × scheme with restarts; returns the row.
fn run_object(obj: &TurntableObject, setting: Setting, scheme: SchemeKind,
              cfg: &CaltechConfig, backend: &crate::runtime::SharedBackend,
              out_dir: &Path) -> Result<CaltechRow> {
    let data = sfm::ppca_input(&obj.measurements);
    let (baseline, _) = sfm::svd_structure(&obj.measurements)?;
    let blocks = sfm::split_frames(&data, obj.frames, CAMERAS);
    let n_padded = blocks.iter().map(|b| b.cols()).max().unwrap();
    let graph = setting.topo.build(CAMERAS)?;

    let mut curves = Vec::new();
    let mut iters = Vec::new();
    let mut finals = Vec::new();
    for seed in 0..cfg.seeds as u64 {
        let mut spec = DppcaSpec::new(blocks.clone(), n_padded, 3, graph.clone(), scheme);
        spec.params = SchemeParams { t_max: setting.t_max, ..Default::default() };
        spec.init = InitStrategy::LocalPca;
        spec.seed = seed;
        spec.max_iters = cfg.max_iters;
        spec.reference = Some(&baseline);
        let result = run_dppca(&spec, backend.clone())?;
        iters.push(result.iterations as f64);
        finals.push(result.final_angle);
        curves.push(result.recorder.error_curve());
    }
    let curve = stats::median_curve(&curves);
    let mut w = CsvWriter::create(
        out_dir.join(format!("caltech_{}_{}_{}.csv", obj.name,
                             setting_name(setting), scheme.name())),
        &["iter", "median_angle_deg"],
    )?;
    for (t, v) in curve.iter().enumerate() {
        w.row(&[t.to_string(), fnum(*v)])?;
    }
    w.finish()?;
    Ok(CaltechRow {
        object: obj.name.clone(),
        setting: setting_name(setting),
        scheme,
        median_iterations: stats::median(&iters),
        median_final_angle: stats::median(&finals),
        curve,
    })
}

/// Full sweep (all objects × settings × schemes).
pub fn run(cfg: &CaltechConfig, out_dir: &Path) -> Result<Vec<CaltechRow>> {
    let backend = cfg.backend.build()?;
    let objects = turntable_objects(cfg.data_seed);
    let selected: Vec<&TurntableObject> = objects
        .iter()
        .filter(|o| cfg.objects.is_empty() || cfg.objects.contains(&o.name))
        .collect();
    let mut rows = Vec::new();
    for obj in selected {
        for setting in SETTINGS {
            for &scheme in &cfg.schemes {
                rows.push(run_object(obj, setting, scheme, cfg, &backend, out_dir)?);
            }
        }
    }
    let mut w = CsvWriter::create(out_dir.join("caltech_summary.csv"),
                                  &["object", "setting", "scheme",
                                    "median_iters", "median_final_angle_deg"])?;
    for r in &rows {
        w.row(&[r.object.clone(), r.setting.clone(), r.scheme.name().to_string(),
                fnum(r.median_iterations), fnum(r.median_final_angle)])?;
    }
    w.finish()?;
    Ok(rows)
}

pub fn print_summary(rows: &[CaltechRow]) {
    println!("{:<12} {:<18} {:<12} {:>12} {:>16}", "object", "setting", "scheme",
             "median iters", "final angle");
    for r in rows {
        println!("{:<12} {:<18} {:<12} {:>12.1} {:>16.4}", r.object, r.setting,
                 r.scheme.name(), r.median_iterations, r.median_final_angle);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_object_single_setting() {
        let dir = std::env::temp_dir().join("fadmm_caltech_test");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = CaltechConfig {
            seeds: 1,
            max_iters: 40,
            schemes: vec![SchemeKind::Nap],
            objects: vec!["Standing".to_string()],
            ..Default::default()
        };
        let rows = run(&cfg, &dir).unwrap();
        assert_eq!(rows.len(), SETTINGS.len());
        for r in &rows {
            assert!(r.median_final_angle.is_finite());
        }
        describe(&dir, 0).unwrap();
        assert!(dir.join("caltech_objects.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
