//! E1/E2 — paper Fig. 2: synthetic D-PPCA, subspace-angle error curves.
//!
//! Setup (paper §5.1): 500 samples of 20-dim observations from a 5-dim
//! subspace N(0, I), measurement noise N(0, 0.2·I), samples split evenly
//! over the nodes, η⁰ = 10, 20 random restarts, median curves reported.
//!
//! * axis "size": complete graphs with J ∈ {12, 16, 20};
//! * axis "topology": J = 20 with complete / ring / cluster graphs.

use std::path::Path;

use super::common::{paper_schemes, run_dppca, BackendChoice, DppcaSpec};
use crate::data::{even_split, SubspaceSpec};
use crate::error::Result;
use crate::graph::Topology;
use crate::linalg::Mat;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::util::csv::{fnum, CsvWriter};
use crate::util::rng::Pcg;
use crate::util::stats;

/// One (configuration, scheme) summary row.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub config: String,
    pub scheme: SchemeKind,
    pub median_iterations: f64,
    pub median_final_angle: f64,
    /// median error curve (extended to the longest run)
    pub curve: Vec<f64>,
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct Fig2Config {
    pub seeds: usize,
    pub backend: BackendChoice,
    pub max_iters: usize,
    pub schemes: Vec<SchemeKind>,
    /// include the size axis (Fig. 2a-c)
    pub axis_size: bool,
    /// include the topology axis (Fig. 2c-e)
    pub axis_topology: bool,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            seeds: 20,
            backend: BackendChoice::Native,
            max_iters: 400,
            schemes: paper_schemes().to_vec(),
            axis_size: true,
            axis_topology: true,
        }
    }
}

/// Run the sweep, write CSVs under `out_dir`, return the summary rows.
pub fn run(cfg: &Fig2Config, out_dir: &Path) -> Result<Vec<Fig2Row>> {
    let mut rows = Vec::new();
    let mut targets: Vec<(String, usize, Topology, usize)> = Vec::new();
    if cfg.axis_size {
        for &j in &[12usize, 16, 20] {
            targets.push((format!("size_J{j}"), j, Topology::Complete,
                          even_split(500, j).padded));
        }
    }
    if cfg.axis_topology {
        for topo in [Topology::Complete, Topology::Ring, Topology::Cluster] {
            targets.push((format!("topology_{}", topo.name()), 20, topo,
                          even_split(500, 20).padded));
        }
    }

    let backend = cfg.backend.build()?;
    for (config_name, j, topo, n_padded) in targets {
        let graph = topo.build(j)?;
        for &scheme in &cfg.schemes {
            let mut curves: Vec<Vec<f64>> = Vec::with_capacity(cfg.seeds);
            let mut iters: Vec<f64> = Vec::with_capacity(cfg.seeds);
            let mut finals: Vec<f64> = Vec::with_capacity(cfg.seeds);
            for seed in 0..cfg.seeds as u64 {
                // the *data* is fixed across restarts (paper: 20 random
                // initializations of the same problem)
                let data = SubspaceSpec::default().generate(&mut Pcg::seed(7));
                let part = even_split(500, j);
                let blocks: Vec<Mat> = part
                    .ranges
                    .iter()
                    .map(|&(lo, hi)| data.x.col_slice(lo, hi))
                    .collect();
                let mut spec = DppcaSpec::new(blocks, n_padded, 5, graph.clone(), scheme);
                spec.params = SchemeParams::default();
                spec.seed = seed;
                spec.max_iters = cfg.max_iters;
                spec.reference = Some(&data.w_true);
                let result = run_dppca(&spec, backend.clone())?;
                iters.push(result.iterations as f64);
                finals.push(result.final_angle);
                curves.push(result.recorder.error_curve());
            }
            let median_curve = stats::median_curve(&curves);
            let mut w = CsvWriter::create(
                out_dir.join(format!("fig2_{config_name}_{}.csv", scheme.name())),
                &["iter", "median_angle_deg"],
            )?;
            for (t, v) in median_curve.iter().enumerate() {
                w.row(&[t.to_string(), fnum(*v)])?;
            }
            w.finish()?;
            rows.push(Fig2Row {
                config: config_name.clone(),
                scheme,
                median_iterations: stats::median(&iters),
                median_final_angle: stats::median(&finals),
                curve: median_curve,
            });
        }
    }

    // summary table
    let mut w = CsvWriter::create(out_dir.join("fig2_summary.csv"),
                                  &["config", "scheme", "median_iters",
                                    "median_final_angle_deg"])?;
    for r in &rows {
        w.row(&[r.config.clone(), r.scheme.name().to_string(),
                fnum(r.median_iterations), fnum(r.median_final_angle)])?;
    }
    w.finish()?;
    Ok(rows)
}

/// Pretty-print the summary (CLI output).
pub fn print_summary(rows: &[Fig2Row]) {
    println!("{:<22} {:<12} {:>12} {:>18}", "config", "scheme", "median iters",
             "final angle (deg)");
    for r in rows {
        println!("{:<22} {:<12} {:>12.1} {:>18.4}", r.config, r.scheme.name(),
                 r.median_iterations, r.median_final_angle);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miniature_sweep_produces_all_rows() {
        let dir = std::env::temp_dir().join("fadmm_fig2_test");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = Fig2Config {
            seeds: 1,
            max_iters: 30,
            schemes: vec![SchemeKind::Fixed, SchemeKind::Ap],
            axis_size: false,
            axis_topology: true,
            ..Default::default()
        };
        let rows = run(&cfg, &dir).unwrap();
        assert_eq!(rows.len(), 3 * 2); // 3 topologies × 2 schemes
        assert!(dir.join("fig2_summary.csv").exists());
        assert!(dir.join("fig2_topology_ring_admm-ap.csv").exists());
        for r in &rows {
            assert!(r.median_final_angle.is_finite());
            assert!(!r.curve.is_empty());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
