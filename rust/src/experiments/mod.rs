//! Paper-experiment harness (see DESIGN.md §4 for the experiment index).
//!
//! Each submodule regenerates one paper artifact end to end — workload
//! generation, distributed optimization across every penalty scheme, and
//! CSV emission of the same rows/series the paper plots:
//!
//! * [`fig2`] — synthetic D-PPCA, graph size & topology sweeps (Fig. 2);
//! * [`caltech`] — turntable SfM curves (Fig. 3 / Fig. 5, plus the Fig. 4
//!   dataset description table);
//! * [`hopkins`] — trajectory-corpus mean-iteration table (§5.2);
//! * [`ablations`] — η⁰ sensitivity, NAP budget, VP μ/reset (ours);
//! * [`net_scenarios`] — loss × latency × churn fault matrix over the
//!   simulated-network runtime (ours; [`crate::net`]);
//! * [`cluster_scenarios`] — machines × loss × collective × scheme matrix
//!   over the hybrid cluster runtime, reporting extra rounds vs the
//!   oracle fold (ours; [`crate::cluster`]).
//!
//! ## How to read a run report (`repro <cmd> --obs report.json`)
//!
//! Any subcommand accepts `--obs FILE`; the launcher arms the global
//! telemetry sink ([`crate::obs`]) and, after the experiment finishes,
//! writes the merged registry of every run as JSON to `FILE` and
//! Prometheus text to `FILE.prom`. Reading the JSON:
//!
//! * `counters` — monotone totals, *summed across every run in the
//!   sweep*. `fadmm_rounds_total` is the committed-iteration total;
//!   `fadmm_net_*_total` mirror [`crate::metrics::NetCounters`]
//!   (`sent`/`delivered`/`dropped_*` tell you the fault load);
//!   `fadmm_trace_events_total` vs `fadmm_trace_dropped_total` say how
//!   much of the flight recorder survived its capacity bound.
//! * `gauges` — last-run snapshots (`fadmm_iterations`,
//!   `fadmm_converged`, `fadmm_virtual_time`, `fadmm_machines`,
//!   `fadmm_workers`): useful for single runs, last-writer-wins in
//!   sweeps.
//! * `histograms` — power-of-two-bucketed wall-clock nanoseconds per
//!   phase (`fadmm_phase_{solve,reduce,observe}_ns`,
//!   `fadmm_boundary_io_ns`, `fadmm_collective_fold_ns`,
//!   `fadmm_pool_dispatch_ns`). `count` is the number of spans, `sum`
//!   total ns; bucket `i` holds durations in `[2^(i-1), 2^i)` ns. A
//!   solve/fold `sum` ratio far from the sharded baseline is the first
//!   place to look when a distributed run is slow.
//!
//! Wall-clock spans make the report non-deterministic across hosts;
//! every counter is deterministic for a fixed seed (instrumentation is
//! bit-transparent — the cluster parity tests pin that). The fault
//! sweeps additionally write per-run counter rows to
//! `net_counters.json` / `cluster_counters.json` in `--out`, keyed by
//! scenario cell, via the single
//! [`crate::metrics::NetCounters::summary_json`] path.
//!
//! The metrics report is one of three run artifacts — `--trace FILE`
//! adds a Chrome/Perfetto trace with per-round critical-path
//! attribution, and `--series FILE` a per-committed-round convergence
//! CSV; see the observability guide in [`crate::obs`] for how to read
//! each. When `--series` (or `--trace`) arms the sweeps, the fault
//! matrices also interleave per-round series rows into
//! `net_series.csv` / `cluster_series.csv` next to the counter files,
//! prefixed with the same scenario-cell key columns.

pub mod ablations;
pub mod caltech;
pub mod cluster_scenarios;
pub mod common;
pub mod fig2;
pub mod hopkins;
pub mod net_scenarios;

pub use common::{BackendChoice, DppcaRunResult, DppcaSpec};
