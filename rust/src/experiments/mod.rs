//! Paper-experiment harness (see DESIGN.md §4 for the experiment index).
//!
//! Each submodule regenerates one paper artifact end to end — workload
//! generation, distributed optimization across every penalty scheme, and
//! CSV emission of the same rows/series the paper plots:
//!
//! * [`fig2`] — synthetic D-PPCA, graph size & topology sweeps (Fig. 2);
//! * [`caltech`] — turntable SfM curves (Fig. 3 / Fig. 5, plus the Fig. 4
//!   dataset description table);
//! * [`hopkins`] — trajectory-corpus mean-iteration table (§5.2);
//! * [`ablations`] — η⁰ sensitivity, NAP budget, VP μ/reset (ours);
//! * [`net_scenarios`] — loss × latency × churn fault matrix over the
//!   simulated-network runtime (ours; [`crate::net`]);
//! * [`cluster_scenarios`] — machines × loss × collective × scheme matrix
//!   over the hybrid cluster runtime, reporting extra rounds vs the
//!   oracle fold (ours; [`crate::cluster`]).

pub mod ablations;
pub mod caltech;
pub mod cluster_scenarios;
pub mod common;
pub mod fig2;
pub mod hopkins;
pub mod net_scenarios;

pub use common::{BackendChoice, DppcaRunResult, DppcaSpec};
