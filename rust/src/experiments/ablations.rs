//! A1-A3 — ablations over the design choices DESIGN.md calls out.
//!
//! * `eta0` — η⁰ sensitivity: the adaptive schemes' selling point is
//!   reduced dependence on the initial penalty (paper §2.1 on He et al.);
//! * `budget` — NAP's (𝒯, α, β) sweep: convergence cost of the budget;
//! * `vp` — VP's μ threshold and the homogeneous reset on/off (the paper
//!   argues the reset is required — §3.1).

use std::path::Path;

use super::common::{run_dppca, BackendChoice, DppcaSpec};
use crate::data::{even_split, SubspaceSpec};
use crate::error::Result;
use crate::graph::Topology;
use crate::linalg::Mat;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::util::csv::{fnum, CsvWriter};
use crate::util::rng::Pcg;
use crate::util::stats;

#[derive(Debug, Clone)]
pub struct AblationConfig {
    pub seeds: usize,
    pub backend: BackendChoice,
    pub max_iters: usize,
    /// nodes in the (complete) graph
    pub j: usize,
}

impl Default for AblationConfig {
    fn default() -> Self {
        AblationConfig { seeds: 5, backend: BackendChoice::Native, max_iters: 400, j: 20 }
    }
}

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub name: String,
    pub variant: String,
    pub scheme: SchemeKind,
    pub median_iters: f64,
    pub median_final_angle: f64,
}

fn run_variant(cfg: &AblationConfig, scheme: SchemeKind, params: SchemeParams,
               backend: &crate::runtime::SharedBackend)
               -> Result<(f64, f64)> {
    let data = SubspaceSpec::default().generate(&mut Pcg::seed(7));
    let part = even_split(500, cfg.j);
    let blocks: Vec<Mat> = part
        .ranges
        .iter()
        .map(|&(lo, hi)| data.x.col_slice(lo, hi))
        .collect();
    let graph = Topology::Complete.build(cfg.j)?;
    let mut iters = Vec::new();
    let mut finals = Vec::new();
    for seed in 0..cfg.seeds as u64 {
        let mut spec = DppcaSpec::new(blocks.clone(), part.padded, 5, graph.clone(), scheme);
        spec.params = params;
        spec.seed = seed;
        spec.max_iters = cfg.max_iters;
        spec.reference = Some(&data.w_true);
        let r = run_dppca(&spec, backend.clone())?;
        iters.push(r.iterations as f64);
        finals.push(r.final_angle);
    }
    Ok((stats::median(&iters), stats::median(&finals)))
}

/// A1: η⁰ ∈ {1, 10, 100} across Fixed / VP / AP / NAP.
pub fn eta0(cfg: &AblationConfig, out: &Path) -> Result<Vec<AblationRow>> {
    let backend = cfg.backend.build()?;
    let mut rows = Vec::new();
    for &eta0 in &[1.0, 10.0, 100.0] {
        for scheme in [SchemeKind::Fixed, SchemeKind::Vp, SchemeKind::Ap, SchemeKind::Nap] {
            let params = SchemeParams { eta0, ..Default::default() };
            let (mi, ma) = run_variant(cfg, scheme, params, &backend)?;
            rows.push(AblationRow {
                name: "eta0".into(),
                variant: format!("eta0={eta0}"),
                scheme,
                median_iters: mi,
                median_final_angle: ma,
            });
        }
    }
    write_rows(&rows, out, "ablation_eta0.csv")?;
    Ok(rows)
}

/// A2: NAP budget sweep (𝒯, α, β).
pub fn budget(cfg: &AblationConfig, out: &Path) -> Result<Vec<AblationRow>> {
    let backend = cfg.backend.build()?;
    let mut rows = Vec::new();
    for &budget in &[0.5, 1.0, 2.0] {
        for &alpha in &[0.3, 0.5, 0.9] {
            let params = SchemeParams { budget, alpha, ..Default::default() };
            let (mi, ma) = run_variant(cfg, SchemeKind::Nap, params, &backend)?;
            rows.push(AblationRow {
                name: "budget".into(),
                variant: format!("T={budget};alpha={alpha}"),
                scheme: SchemeKind::Nap,
                median_iters: mi,
                median_final_angle: ma,
            });
        }
    }
    for &beta in &[0.01, 0.1, 0.5] {
        let params = SchemeParams { beta, ..Default::default() };
        let (mi, ma) = run_variant(cfg, SchemeKind::Nap, params, &backend)?;
        rows.push(AblationRow {
            name: "budget".into(),
            variant: format!("beta={beta}"),
            scheme: SchemeKind::Nap,
            median_iters: mi,
            median_final_angle: ma,
        });
    }
    write_rows(&rows, out, "ablation_budget.csv")?;
    Ok(rows)
}

/// A3: VP μ threshold and reset-vs-freeze at t_max.
pub fn vp(cfg: &AblationConfig, out: &Path) -> Result<Vec<AblationRow>> {
    let backend = cfg.backend.build()?;
    let mut rows = Vec::new();
    for &mu in &[2.0, 10.0, 50.0] {
        for &reset in &[true, false] {
            let params = SchemeParams { mu, vp_reset: reset, ..Default::default() };
            let (mi, ma) = run_variant(cfg, SchemeKind::Vp, params, &backend)?;
            rows.push(AblationRow {
                name: "vp".into(),
                variant: format!("mu={mu};reset={reset}"),
                scheme: SchemeKind::Vp,
                median_iters: mi,
                median_final_angle: ma,
            });
        }
    }
    write_rows(&rows, out, "ablation_vp.csv")?;
    Ok(rows)
}

fn write_rows(rows: &[AblationRow], out: &Path, file: &str) -> Result<()> {
    let mut w = CsvWriter::create(out.join(file),
                                  &["name", "variant", "scheme", "median_iters",
                                    "median_final_angle_deg"])?;
    for r in rows {
        w.row(&[r.name.clone(), r.variant.clone(), r.scheme.name().to_string(),
                fnum(r.median_iters), fnum(r.median_final_angle)])?;
    }
    w.finish()
}

pub fn print_summary(rows: &[AblationRow]) {
    println!("{:<8} {:<22} {:<12} {:>12} {:>16}", "ablation", "variant", "scheme",
             "median iters", "final angle");
    for r in rows {
        println!("{:<8} {:<22} {:<12} {:>12.1} {:>16.4}", r.name, r.variant,
                 r.scheme.name(), r.median_iters, r.median_final_angle);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vp_ablation_smoke() {
        let dir = std::env::temp_dir().join("fadmm_ablation_test");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = AblationConfig { seeds: 1, max_iters: 25, j: 6, ..Default::default() };
        let rows = vp(&cfg, &dir).unwrap();
        assert_eq!(rows.len(), 6);
        assert!(dir.join("ablation_vp.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
