//! Hybrid cluster-runtime cost: wall-clock of the machine-level
//! discrete-event loop, plus the scenario metrics the ROADMAP tracks —
//! rounds to consensus, extra rounds vs the oracle fold, and virtual time
//! — for the tree and gossip collectives under a clean link vs 10% loss,
//! and the per-round cost of the simulated driver vs the in-process
//! thread transport (same protocol, different `Transport` backend).
//! Writes the machine-readable `BENCH_cluster.json` (same layout contract
//! as `BENCH_net.json`: a `results` array from the Bencher plus a derived
//! `scenario` object for gates/dashboards).

use fadmm::cluster::inproc::run_inproc;
use fadmm::cluster::{ClusterConfig, ClusterReport, ClusterRunner, CollectiveKind};
use fadmm::consensus::solvers::QuadraticNode;
use fadmm::coordinator::{ShardedConfig, ShardedRunner, SolverFactory};
use fadmm::experiments::common::quad_problem_factory;
use fadmm::graph::Topology;
use fadmm::net::{FaultPlan, LinkModel};
use fadmm::penalty::SchemeKind;
use fadmm::pool::ExecMode;
use fadmm::util::bench::{black_box, Bencher};
use fadmm::util::json::{num, obj, s, Json};

const N: usize = 24;
const DIM: usize = 3;
const MACHINES: usize = 4;

fn factory(seed: u64) -> SolverFactory<QuadraticNode> {
    quad_problem_factory(N, DIM, seed)
}

fn lossy_plan(loss: f64) -> FaultPlan {
    if loss <= 0.0 {
        FaultPlan::none()
    } else {
        FaultPlan {
            link: LinkModel { base: 2, jitter: 4, loss, dup: 0.02 },
            ..FaultPlan::none()
        }
    }
}

fn run_once(scheme: SchemeKind, collective: CollectiveKind, loss: f64, tol: f64,
            max_iters: usize) -> ClusterReport {
    let runner = ClusterRunner::new(
        Topology::Ring.build(N).unwrap(),
        ClusterConfig {
            scheme,
            tol,
            max_iters,
            seed: 5,
            machines: MACHINES,
            workers: 1,
            collective,
            max_staleness: if loss > 0.0 { 1 } else { 0 },
            silence_timeout: 16,
            collective_timeout: 24,
            fallback_after: 2,
            tracing: false,
            ..Default::default()
        },
        lossy_plan(loss),
        factory(77),
    )
    .unwrap();
    runner.run()
}

fn oracle_rounds(scheme: SchemeKind, tol: f64, max_iters: usize) -> usize {
    ShardedRunner::new(
        Topology::Ring.build(N).unwrap(),
        ShardedConfig { scheme, tol, max_iters, seed: 5, workers: MACHINES,
                        ..Default::default() },
    )
    .run(factory(77))
    .unwrap()
    .iterations
}

fn main() {
    let mut b = Bencher::from_env();
    let mut scenario_fields: Vec<(String, Json)> = Vec::new();

    println!("== event-loop wall cost (ring {N}, {MACHINES} machines, ADMM-AP, \
              fixed 80 rounds) ==");
    b.bench("cluster tree zero-fault 80 rounds", || {
        black_box(run_once(SchemeKind::Ap, CollectiveKind::Tree, 0.0, 0.0, 80));
    });
    b.bench("cluster gossip zero-fault 80 rounds", || {
        black_box(run_once(SchemeKind::Ap, CollectiveKind::Gossip, 0.0, 0.0, 80));
    });
    b.bench("cluster tree 10% loss 80 rounds", || {
        black_box(run_once(SchemeKind::Ap, CollectiveKind::Tree, 0.10, 0.0, 80));
    });

    println!("== rounds-to-consensus and extra rounds vs the oracle fold \
              (tol 1e-6) ==");
    // the oracle depends only on the scheme — solve each once, not per cell
    let schemes = [SchemeKind::Fixed, SchemeKind::Rb, SchemeKind::Nap];
    let oracles: Vec<usize> =
        schemes.iter().map(|&s| oracle_rounds(s, 1e-6, 600)).collect();
    for (name, loss) in [("clean", 0.0f64), ("loss10", 0.10)] {
        for collective in CollectiveKind::ALL {
            for (si, &scheme) in schemes.iter().enumerate() {
                let report = run_once(scheme, collective, loss, 1e-6, 600);
                let oracle = oracles[si];
                let extra = report.iterations as i64 - oracle as i64;
                let last_primal = report
                    .recorder
                    .stats
                    .last()
                    .map(|st| st.max_primal)
                    .unwrap_or(f64::NAN);
                println!(
                    "{name:<8} {:<7} {:<12} rounds {:>4} oracle {:>4} extra {:>4} \
                     vtime {:>7} dropped {:>5} primal {:.3e}",
                    collective.name(), scheme.name(), report.iterations, oracle,
                    extra, report.virtual_time,
                    report.counters.dropped_total(), last_primal,
                );
                let key = format!("{name}_{}_{}", collective.name(), scheme.name());
                scenario_fields.push((
                    key,
                    obj(vec![
                        ("rounds", num(report.iterations as f64)),
                        ("oracle_rounds", num(oracle as f64)),
                        ("extra_rounds", num(extra as f64)),
                        ("virtual_time", num(report.virtual_time as f64)),
                        ("converged", num(if report.converged { 1.0 } else { 0.0 })),
                        ("final_primal", num(last_primal)),
                        ("dropped", num(report.counters.dropped_total() as f64)),
                        ("counters", report.counters.summary_json()),
                    ]),
                ));
            }
        }
    }

    println!("== pool vs scoped execution (link latency 2, overlap win) ==");
    // deterministic link delay with zero loss: every boundary batch is in
    // flight when a machine reaches its phase-A barrier, so pool mode must
    // overlap the interior solves with the wait; scoped mode stalls whole
    const POOL_ROUNDS: usize = 80;
    let mut pool_fields: Vec<(&str, Json)> = Vec::new();
    for dim in [3usize, 32] {
        let run_exec = |exec| {
            ClusterRunner::new(
                Topology::Ring.build(N).unwrap(),
                ClusterConfig {
                    scheme: SchemeKind::Ap,
                    tol: 0.0,
                    max_iters: POOL_ROUNDS,
                    seed: 5,
                    machines: MACHINES,
                    workers: 2,
                    exec,
                    tracing: false,
                    ..Default::default()
                },
                FaultPlan {
                    link: LinkModel { base: 2, jitter: 0, loss: 0.0, dup: 0.0 },
                    ..FaultPlan::none()
                },
                quad_problem_factory(N, dim, 77),
            )
            .unwrap()
            .run()
        };
        let pool_name = format!("cluster pool dim {dim} x {POOL_ROUNDS} rounds");
        let scoped_name = format!("cluster scoped dim {dim} x {POOL_ROUNDS} rounds");
        let mut last_report = None;
        b.bench(&pool_name, || {
            last_report = Some(run_exec(ExecMode::Pool));
        });
        let pool_report = last_report.expect("bench ran at least once");
        b.bench(&scoped_name, || {
            black_box(run_exec(ExecMode::Scoped));
        });
        let pool_ns = b.result(&pool_name).unwrap().mean_ns / POOL_ROUNDS as f64;
        let scoped_ns = b.result(&scoped_name).unwrap().mean_ns / POOL_ROUNDS as f64;
        let overlaps = pool_report.counters.overlap_dispatches;
        assert!(overlaps > 0,
                "latency plan must drive interior overlap (got {overlaps})");
        println!("  dim={dim}: pool {pool_ns:.0}ns/iter vs scoped {scoped_ns:.0}ns/iter \
                  ({}); overlap dispatches {overlaps}",
                 if pool_ns <= scoped_ns { "pool wins" } else { "scoped wins" });
        let key = if dim == 3 { "dim_3" } else { "dim_32" };
        pool_fields.push((key, obj(vec![
            ("pool_ns_per_iter", num(pool_ns)),
            ("scoped_ns_per_iter", num(scoped_ns)),
            ("pool_win", Json::Bool(pool_ns <= scoped_ns)),
            ("overlap_dispatches", num(overlaps as f64)),
        ])));
    }
    pool_fields.push(("rounds", num(POOL_ROUNDS as f64)));
    pool_fields.push(("crossover_note", s(
        "the overlap win scales with interior solve cost: marginal at dim 3, \
         larger at dim 32 where hidden compute per boundary wait grows")));

    println!("== transport: simulated driver vs in-process threads ==");
    // same protocol, two Transport backends: the deterministic
    // single-threaded simulator vs one OS thread per machine over a
    // channel mesh. The iteration-count equality is the zero-fault
    // transport contract from `cluster::inproc`, re-checked on the
    // bench configuration; the ns/iter gap prices real scheduling +
    // channel hops against simulated delivery.
    const TRANSPORT_ROUNDS: usize = 60;
    let transport_cfg = ClusterConfig {
        scheme: SchemeKind::Ap,
        tol: 0.0,
        max_iters: TRANSPORT_ROUNDS,
        seed: 5,
        machines: MACHINES,
        workers: 1,
        collective: CollectiveKind::Tree,
        // wall ms on the channel transport, virtual ticks in the sim —
        // unreachable either way at zero faults
        silence_timeout: 5_000,
        collective_timeout: 5_000,
        tracing: false,
        ..Default::default()
    };
    let mut sim_iters = 0usize;
    b.bench("transport sim 60 rounds", || {
        let report = ClusterRunner::new(
            Topology::Ring.build(N).unwrap(),
            transport_cfg,
            FaultPlan::none(),
            factory(77),
        )
        .unwrap()
        .run();
        sim_iters = report.iterations;
    });
    let mut inproc_iters = 0usize;
    b.bench("transport inproc 60 rounds", || {
        let reports = run_inproc(&Topology::Ring.build(N).unwrap(),
                                 transport_cfg, factory(77))
            .unwrap();
        inproc_iters = reports
            .iter()
            .find(|r| r.is_holder)
            .map(|r| r.iterations)
            .unwrap_or(0);
    });
    assert_eq!(sim_iters, inproc_iters,
               "transport contract: same committed iteration count on \
                both backends");
    let sim_ns = b.result("transport sim 60 rounds").unwrap().mean_ns
        / TRANSPORT_ROUNDS as f64;
    let inproc_ns = b.result("transport inproc 60 rounds").unwrap().mean_ns
        / TRANSPORT_ROUNDS as f64;
    println!("  sim {sim_ns:.0}ns/iter vs in-process threads \
              {inproc_ns:.0}ns/iter; both committed {sim_iters} rounds");
    let transport = obj(vec![
        ("rounds", num(TRANSPORT_ROUNDS as f64)),
        ("sim_ns_per_iter", num(sim_ns)),
        ("inproc_ns_per_iter", num(inproc_ns)),
        ("iterations", num(sim_iters as f64)),
        ("iteration_counts_equal", Json::Bool(sim_iters == inproc_iters)),
    ]);

    let scenario = obj(scenario_fields
        .iter()
        .map(|(k, v)| (k.as_str(), v.clone()))
        .collect());
    let extra = vec![
        ("nodes", num(N as f64)),
        ("dim", num(DIM as f64)),
        ("machines", num(MACHINES as f64)),
        ("topology", s("ring")),
        ("scenario", scenario),
        ("pool", obj(pool_fields)),
        ("transport", transport),
    ];
    match b.write_json("cluster", extra) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("bench_cluster: could not write JSON: {e}"),
    }
}
