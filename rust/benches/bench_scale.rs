//! Million-node scale envelope: iterations/sec, bytes/node and peak RSS
//! for the sharded runner on ring and power-law graphs at 1e4–1e6 nodes,
//! in both parameter precisions.
//!
//! Tiers (driven by env vars, matching `ci.sh` / `bench_baseline.sh`):
//!
//! * `FADMM_BENCH_FAST=1` — smoke: the 1e4 ring cell only (the tier
//!   `ci.sh` runs and gates bytes/node + the f32/f64 param ratio on).
//! * default — 1e4 and 1e5, ring + power-law.
//! * `FADMM_BENCH_SCALE_FULL=1` — adds the 1e6 cells (minutes, not CI).
//!
//! Per cell it builds the CSR graph, accounts the arena layout *without*
//! running (both precisions — the f32/f64 `param_bytes` ratio must be
//! exactly 0.5 because shard padding rounds to the same 64-byte
//! boundaries), then times fixed-iteration runs at each precision and
//! reports the max final-θ divergence between them. Peak RSS is the
//! process high-water mark (`VmHWM`), so it is monotone across cells;
//! cells run smallest-first so the first exceedance is attributable.
//! Writes the machine-readable `BENCH_scale.json` at the repo root.

use std::sync::Arc;

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::coordinator::{ParamArena, Precision, ShardedConfig, ShardedRunner,
                         SolverFactory};
use fadmm::graph::{shard_ranges, Topology};
use fadmm::penalty::SchemeKind;
use fadmm::util::bench::Bencher;
use fadmm::util::json::{arr, num, obj, s, Json};
use fadmm::util::rng::Pcg;

const DIM: usize = 4;

fn quad_factory() -> SolverFactory<QuadraticNode> {
    // lazy per-node construction: no O(n) precompute that would dominate
    // the 1e6 cells' footprint before the arena is even built
    Arc::new(|i| {
        let mut rng = Pcg::seed(11 + i as u64);
        QuadraticNode::random(DIM, &mut rng)
    })
}

/// Process peak-RSS high-water mark in KiB (0.0 where /proc is absent).
fn peak_rss_kb() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|status| {
            status.lines().find(|l| l.starts_with("VmHWM:")).and_then(|l| {
                l.split_whitespace().nth(1).and_then(|v| v.parse::<f64>().ok())
            })
        })
        .unwrap_or(0.0)
}

fn iters_for(n: usize) -> usize {
    match n {
        0..=10_000 => 20,
        10_001..=100_000 => 5,
        _ => 2,
    }
}

fn main() {
    let fast = std::env::var("FADMM_BENCH_FAST").is_ok();
    let full = std::env::var("FADMM_BENCH_SCALE_FULL").is_ok();
    let mut b = Bencher::from_env();

    let sizes: &[usize] = if fast {
        &[10_000]
    } else if full {
        &[10_000, 100_000, 1_000_000]
    } else {
        &[10_000, 100_000]
    };
    let topos: &[Topology] =
        if fast { &[Topology::Ring] } else { &[Topology::Ring, Topology::PowerLaw] };

    let mut cells: Vec<Json> = Vec::new();
    for &n in sizes {
        for &topo in topos {
            let iters = iters_for(n);
            let g = topo.build(n).unwrap();
            let cell = format!("{} {n}", topo.name());

            // -- layout accounting (no run needed): graph + both arenas
            // over the same shard split the runner would use
            let workers = std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
                .min(n);
            let ranges = shard_ranges(&g, workers);
            let arena64: ParamArena = ParamArena::new_sharded(&g, DIM, &ranges);
            let arena32: ParamArena<f32> = ParamArena::new_sharded(&g, DIM, &ranges);
            let bytes_node_f64 =
                (g.heap_bytes() + arena64.heap_bytes()) as f64 / n as f64;
            let bytes_node_f32 =
                (g.heap_bytes() + arena32.heap_bytes()) as f64 / n as f64;
            let param_ratio =
                arena32.param_bytes() as f64 / arena64.param_bytes() as f64;
            assert!(param_ratio <= 0.5 + 1e-12,
                    "f32 params must cost at most half of f64 (got {param_ratio})");
            drop((arena64, arena32)); // release before the timed runs

            // -- timed fixed-iteration runs, both precisions
            let mut per_precision: Vec<(&str, f64, Vec<Vec<f64>>)> = Vec::new();
            for (tag, precision) in
                [("f64", Precision::F64), ("f32", Precision::F32)]
            {
                let runner =
                    ShardedRunner::new(topo.build(n).unwrap(), ShardedConfig {
                        scheme: SchemeKind::Ap,
                        tol: 0.0,
                        max_iters: iters,
                        precision,
                        ..Default::default()
                    });
                let factory = quad_factory();
                let name = format!("{cell} x {iters} iters {tag}");
                let mut last = None;
                b.bench(&name, || {
                    last = Some(runner.run(factory.clone()).unwrap());
                });
                let report = last.expect("bench ran at least once");
                assert_eq!(report.iterations, iters, "scale run must complete");
                let mean_ns = b.result(&name).unwrap().mean_ns;
                let iters_per_sec = iters as f64 * 1e9 / mean_ns;
                per_precision.push((tag, iters_per_sec, report.thetas));
            }
            let (_, ips64, thetas64) = &per_precision[0];
            let (_, ips32, thetas32) = &per_precision[1];
            // f32 storage must not change what the run computes: same
            // trajectory up to accumulated rounding
            let theta_max_dev = thetas64
                .iter()
                .zip(thetas32.iter())
                .flat_map(|(a, b)| a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()))
                .fold(0.0, f64::max);
            assert!(theta_max_dev.is_finite() && theta_max_dev < 1e-1,
                    "f32 and f64 trajectories diverged: {theta_max_dev}");

            let rss = peak_rss_kb();
            println!(
                "  {cell}: {:.1} B/node f64, {:.1} B/node f32 (param ratio \
                 {param_ratio:.3}), {ips64:.1} it/s f64, {ips32:.1} it/s f32, \
                 θ dev {theta_max_dev:.2e}, peak RSS {rss:.0} KiB",
                bytes_node_f64, bytes_node_f32
            );
            cells.push(obj(vec![
                ("name", s(cell.as_str())),
                ("topology", s(topo.name())),
                ("nodes", num(n as f64)),
                ("dim", num(DIM as f64)),
                ("iters", num(iters as f64)),
                ("workers", num(workers as f64)),
                ("bytes_per_node_f64", num(bytes_node_f64)),
                ("bytes_per_node_f32", num(bytes_node_f32)),
                ("f32_param_ratio", num(param_ratio)),
                ("iters_per_sec_f64", num(*ips64)),
                ("iters_per_sec_f32", num(*ips32)),
                ("theta_max_dev_f32_vs_f64", num(theta_max_dev)),
                ("peak_rss_kb", num(rss)),
            ]));
        }
    }

    let tier = if fast { "fast" } else if full { "full" } else { "default" };
    let extra = vec![
        ("tier", s(tier)),
        ("cells", arr(cells)),
        ("peak_rss_note", s(
            "VmHWM is a process high-water mark: monotone across cells, \
             which run smallest-first")),
    ];
    let path = b.write_json("scale", extra).expect("write bench json");
    println!("wrote {}", path.display());
}
