//! Penalty-scheme update cost per scheme (L3 scheduler overhead).
//! The schemes run once per node per iteration, so this must stay
//! negligible next to the node update.

use fadmm::penalty::{make_scheme, NodeObservation, SchemeKind, SchemeParams};
use fadmm::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::from_env();
    let p = SchemeParams::default();
    for deg in [2usize, 19] {
        let f_nb: Vec<f64> = (0..deg).map(|k| 100.0 + k as f64).collect();
        for kind in SchemeKind::ALL {
            let mut scheme = make_scheme(kind, p, deg);
            let mut eta = vec![p.eta0; deg];
            let mut t = 0usize;
            b.bench(&format!("{}/deg{deg}", kind.name()), || {
                let obs = NodeObservation {
                    t,
                    primal_norm: 1.0,
                    dual_norm: 0.5,
                    global_primal: 1.0,
                    global_dual: 0.5,
                    f_self: 101.0,
                    f_self_prev: 102.0,
                    f_neighbors: &f_nb,
                    live: None,
                };
                scheme.update(&obs, &mut eta);
                t = (t + 1) % 50; // keep pre-t_max behaviour hot
                black_box(&eta);
            });
        }
    }
}
