//! E4 end-to-end bench: a 6-object slice of the trajectory corpus per
//! scheme (the table regenerator's unit of work), native backend.

use fadmm::experiments::common::BackendChoice;
use fadmm::experiments::hopkins::{run, HopkinsConfig};
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::from_env();
    for scheme in [SchemeKind::Fixed, SchemeKind::Vp, SchemeKind::VpAp] {
        b.bench(&format!("hopkins 6-object slice {}", scheme.name()), || {
            let dir = std::env::temp_dir().join("fadmm_bench_hopkins");
            let cfg = HopkinsConfig {
                objects: 6,
                seeds: 1,
                max_iters: 300,
                backend: BackendChoice::Native,
                schemes: vec![scheme],
                topologies: vec![Topology::Complete],
                degenerate_frac: 0.0,
                ..Default::default()
            };
            black_box(run(&cfg, &dir).unwrap());
            std::fs::remove_dir_all(&dir).ok();
        });
    }
}
