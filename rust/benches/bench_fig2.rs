//! E1/E2 end-to-end bench: one full consensus iteration of the Fig. 2
//! synthetic configuration (per scheme, per backend). Figure-level cost =
//! per-iteration latency × the median iteration counts in
//! results/fig2_summary.csv.

use fadmm::data::{even_split, SubspaceSpec};
use fadmm::consensus::{Engine, EngineConfig};
use fadmm::dppca::DppcaSolver;
use fadmm::experiments::common::BackendChoice;
use fadmm::linalg::Mat;
use fadmm::penalty::SchemeKind;
use fadmm::util::bench::Bencher;
use fadmm::util::rng::Pcg;

fn build_engine(j: usize, scheme: SchemeKind, backend: BackendChoice)
                -> Engine<DppcaSolver> {
    let data = SubspaceSpec::default().generate(&mut Pcg::seed(7));
    let part = even_split(500, j);
    let shared = backend.build().expect("backend");
    let solvers: Vec<DppcaSolver> = part
        .ranges
        .iter()
        .map(|&(lo, hi)| {
            DppcaSolver::from_padded_block(&data.x.col_slice(lo, hi), part.padded,
                                           5, shared.clone())
                .unwrap()
        })
        .collect();
    Engine::new(fadmm::graph::Topology::Complete.build(j).unwrap(), solvers,
                EngineConfig { scheme, max_iters: usize::MAX, tol: 0.0,
                               ..Default::default() })
}

fn main() {
    let mut b = Bencher::from_env();
    let have_artifacts =
        fadmm::runtime::Manifest::default_dir().join("manifest.json").exists();
    for backend in [BackendChoice::Native, BackendChoice::Xla] {
        if backend == BackendChoice::Xla && !have_artifacts {
            println!("(xla skipped: run `make artifacts`)");
            continue;
        }
        for scheme in [SchemeKind::Fixed, SchemeKind::Vp, SchemeKind::Nap] {
            let mut engine = build_engine(20, scheme, backend);
            let mut t = 0usize;
            b.bench(&format!("fig2 J=20 iter {:?}/{}", backend, scheme.name()), || {
                engine.step(t, &mut |_, _| 0.0);
                t += 1;
            });
        }
    }
}
