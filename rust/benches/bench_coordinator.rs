//! L3 coordination overhead, three ways on the same quadratic consensus
//! problem (the compute is trivial, so the deltas isolate per-iteration
//! messaging/synchronization cost):
//!
//! * the sequential `Engine` (zero coordination — the floor),
//! * a bench-only replica of the deleted thread-per-node mpsc runtime
//!   (the measurement control this PR's runner is judged against),
//! * the sharded worker-pool runner over the zero-copy parameter arena.
//!
//! Also proves the scale claim with 256- and 1024-node ring runs that the
//! thread-per-node design (one OS thread + per-neighbour `Vec` clones per
//! node) was never able to handle, measures the hot loop's allocation
//! hygiene with a counting global allocator (phase A must perform zero
//! allocations; a whole steady-state iteration must too), and writes the
//! machine-readable `BENCH_coordinator.json` at the repo root.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::consensus::{Engine, EngineConfig, LocalSolver};
use fadmm::coordinator::{ShardedConfig, ShardedRunner, SolverFactory};
use fadmm::experiments::common::quad_problem_factory;
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::pool::{threads_spawned, ExecMode};
use fadmm::util::bench::{black_box, Bencher};
use fadmm::util::json::{num, obj, s, Json};
use fadmm::util::rng::Pcg;

const ITERS: usize = 200;
const SCALE_ITERS: usize = 50;
const DIM: usize = 4;

/// Counting allocator: lets the bench assert the hot loop's zero-alloc
/// claim instead of taking it on faith. Counts allocation *events*
/// (alloc + realloc); frees are uninstrumented on purpose.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    f();
    ALLOCATIONS.load(Ordering::SeqCst) - before
}

fn quad_factory() -> SolverFactory<QuadraticNode> {
    Arc::new(|i| {
        let mut rng = Pcg::seed(3 + i as u64);
        QuadraticNode::random(DIM, &mut rng)
    })
}

fn sequential_run(n: usize, topo: Topology, iters: usize) {
    let mut rng = Pcg::seed(3);
    let nodes: Vec<QuadraticNode> =
        (0..n).map(|_| QuadraticNode::random(DIM, &mut rng)).collect();
    let mut engine = Engine::new(topo.build(n).unwrap(), nodes, EngineConfig {
        scheme: SchemeKind::Ap,
        tol: 0.0,
        max_iters: iters,
        ..Default::default()
    });
    black_box(engine.run());
}

fn sharded_run(n: usize, topo: Topology, iters: usize)
               -> fadmm::coordinator::RunnerReport {
    let runner = ShardedRunner::new(topo.build(n).unwrap(), ShardedConfig {
        scheme: SchemeKind::Ap,
        tol: 0.0,
        max_iters: iters,
        ..Default::default()
    });
    runner.run(quad_factory()).unwrap()
}

fn main() {
    let mut b = Bencher::from_env();
    let mut extra: Vec<(&str, Json)> = Vec::new();

    println!("== coordination overhead (complete graph, ADMM-AP) ==");
    for n in [8usize, 20] {
        let seq_name = format!("sequential {n} nodes x {ITERS} iters");
        let legacy_name = format!("legacy-mpsc {n} nodes x {ITERS} iters");
        let sharded_name = format!("sharded {n} nodes x {ITERS} iters");
        b.bench(&seq_name, || sequential_run(n, Topology::Complete, ITERS));
        b.bench(&legacy_name, || {
            black_box(legacy::run(n, Topology::Complete, ITERS));
        });
        b.bench(&sharded_name, || {
            black_box(sharded_run(n, Topology::Complete, ITERS));
        });

        let seq = b.result(&seq_name).unwrap().mean_ns;
        let legacy = b.result(&legacy_name).unwrap().mean_ns;
        let sharded = b.result(&sharded_name).unwrap().mean_ns;
        // coordination overhead = wall time beyond the sequential floor,
        // per ADMM iteration (can go negative once parallel speedup on
        // the local solves outweighs the synchronization cost)
        let overhead_legacy = (legacy - seq) / ITERS as f64;
        let overhead_sharded = (sharded - seq) / ITERS as f64;
        // the ratio is only meaningful while the sharded overhead is
        // positive; below the sequential floor it is reported as null
        let ratio = (overhead_sharded > 0.0)
            .then(|| overhead_legacy / overhead_sharded);
        match ratio {
            Some(r) => println!("  n={n}: overhead/iter legacy {overhead_legacy:.0}ns \
                                 vs sharded {overhead_sharded:.0}ns (ratio {r:.1}x)"),
            None => println!("  n={n}: overhead/iter legacy {overhead_legacy:.0}ns \
                              vs sharded {overhead_sharded:.0}ns (at/below the \
                              sequential floor)"),
        }
        let key = if n == 8 { "nodes_8" } else { "nodes_20" };
        extra.push((key, obj(vec![
            ("sequential_mean_ns", num(seq)),
            ("legacy_mean_ns", num(legacy)),
            ("sharded_mean_ns", num(sharded)),
            ("coordination_overhead_legacy_ns_per_iter", num(overhead_legacy)),
            ("coordination_overhead_sharded_ns_per_iter", num(overhead_sharded)),
            ("overhead_ratio_legacy_over_sharded",
             ratio.map(num).unwrap_or(Json::Null)),
            ("sharded_overhead_at_least_3x_lower",
             Json::Bool(overhead_sharded <= overhead_legacy / 3.0)),
        ])));
    }

    println!("== allocation hygiene (counting global allocator) ==");
    {
        // phase A micro-proof: a warm solver's solve_into must not touch
        // the allocator at all (it is handed the arena block directly)
        let mut rng = Pcg::seed(3);
        let mut solver = QuadraticNode::random(DIM, &mut rng);
        let theta = rng.normal_vec(DIM);
        let lambda = vec![0.0; DIM];
        let eta_wsum: Vec<f64> = theta.iter().map(|v| 2.0 * 20.0 * v).collect();
        let mut out = vec![0.0; DIM];
        solver.solve_into(&theta, &lambda, 20.0, &eta_wsum, &mut out); // warm scratch
        let solve_allocs = allocs_during(|| {
            for _ in 0..1000 {
                solver.solve_into(&theta, &lambda, 20.0, &eta_wsum, &mut out);
            }
        });
        black_box(out[0]);
        println!("  phase A: {solve_allocs} allocations across 1000 solve_into calls");
        assert_eq!(solve_allocs, 0, "phase A (solve_into) must be allocation-free");

        // whole-iteration steady state: two identical runs differing only
        // in iteration count — the delta isolates per-iteration allocs
        // (startup: threads, solvers, arena; all identical across runs)
        let run_allocs =
            |iters: usize| allocs_during(|| { black_box(sharded_run(64, Topology::Ring, iters)); });
        let _ = run_allocs(8); // warm-up run (first-touch effects)
        let base = run_allocs(40);
        let doubled = run_allocs(80);
        let per_iter = (doubled as f64 - base as f64) / 40.0;
        println!("  steady state: {per_iter:.2} allocations per iteration \
                  (40-iter run: {base}, 80-iter run: {doubled})");
        assert_eq!(per_iter, 0.0, "a steady-state iteration must be allocation-free");
        extra.push(("allocation", obj(vec![
            ("phase_a_allocs_per_1000_solves", num(solve_allocs as f64)),
            ("steady_state_allocs_per_iter", num(per_iter)),
        ])));
    }

    println!("== obs instrumentation (zero-alloc with spans on, overhead vs baseline) ==");
    {
        let obs_run = |obs: bool, iters: usize| {
            let runner =
                ShardedRunner::new(Topology::Ring.build(64).unwrap(), ShardedConfig {
                    scheme: SchemeKind::Ap,
                    tol: 0.0,
                    max_iters: iters,
                    obs,
                    ..Default::default()
                });
            runner.run(quad_factory()).unwrap()
        };

        // steady state with spans live must stay allocation-free: span()
        // is one clock read, end() one clock read plus an index into a
        // histogram registered at run start — same 40/80 delta method as
        // the uninstrumented check above
        let run_allocs =
            |iters: usize| allocs_during(|| { black_box(obs_run(true, iters)); });
        let _ = run_allocs(8); // warm-up run (first-touch effects)
        let base = run_allocs(40);
        let doubled = run_allocs(80);
        let per_iter = (doubled as f64 - base as f64) / 40.0;
        println!("  obs-on steady state: {per_iter:.2} allocations per iteration \
                  (40-iter run: {base}, 80-iter run: {doubled})");
        assert_eq!(per_iter, 0.0,
                   "an instrumented steady-state iteration must be allocation-free");

        // instrumented vs baseline wall time, identical configuration —
        // ci.sh gates overhead_pct at FADMM_OBS_GATE_PCT (default 2%)
        let report = obs_run(true, 8);
        let solve = report.obs.hist_by_name("fadmm_phase_solve_ns")
            .expect("instrumented run registers the solve span");
        assert!(solve.count > 0, "obs-on run must record solve spans");
        let base_name = format!("sharded 64 ring x {ITERS} iters obs-off");
        let obs_name = format!("sharded 64 ring x {ITERS} iters obs-on");
        b.bench(&base_name, || { black_box(obs_run(false, ITERS)); });
        b.bench(&obs_name, || { black_box(obs_run(true, ITERS)); });
        let base_ns = b.result(&base_name).unwrap().mean_ns;
        let obs_ns = b.result(&obs_name).unwrap().mean_ns;
        let overhead_pct = (obs_ns - base_ns) / base_ns * 100.0;
        println!("  obs overhead: {overhead_pct:+.2}% \
                  (instrumented {obs_ns:.0}ns vs baseline {base_ns:.0}ns per run)");
        extra.push(("obs", obj(vec![
            ("steady_state_allocs_per_iter_obs_on", num(per_iter)),
            ("baseline_mean_ns", num(base_ns)),
            ("instrumented_mean_ns", num(obs_ns)),
            ("overhead_pct", num(overhead_pct)),
            ("solve_spans_in_8_iter_run", num(solve.count as f64)),
        ])));
    }

    println!("== timeline + series recording (zero-alloc steady state) ==");
    {
        let tl_run = |iters: usize| {
            let mut rng = Pcg::seed(3);
            let nodes: Vec<QuadraticNode> =
                (0..64).map(|_| QuadraticNode::random(DIM, &mut rng)).collect();
            let mut engine =
                Engine::new(Topology::Ring.build(64).unwrap(), nodes, EngineConfig {
                    scheme: SchemeKind::Ap,
                    tol: 0.0,
                    max_iters: iters,
                    obs: true,
                    timeline: true,
                    series: true,
                    ..Default::default()
                });
            engine.run()
        };
        // with recording live the event ring and row buffer were
        // preallocated at construction, so the 40/80 delta must stay
        // zero exactly like the spans-only cell above
        let run_allocs =
            |iters: usize| allocs_during(|| { black_box(tl_run(iters)); });
        let _ = run_allocs(8); // warm-up run (first-touch effects)
        let base = run_allocs(40);
        let doubled = run_allocs(80);
        let per_iter = (doubled as f64 - base as f64) / 40.0;
        println!("  recording-on steady state: {per_iter:.2} allocations per \
                  iteration (40-iter run: {base}, 80-iter run: {doubled})");
        assert_eq!(per_iter, 0.0,
                   "a recorded steady-state iteration must be allocation-free");
        let report = tl_run(8);
        assert_eq!(report.series.len(), 8, "one series row per iteration");
        assert!(report.timeline.len() >= 8 * 4,
                "phase + commit events every iteration");
        extra.push(("timeline", obj(vec![
            ("steady_state_allocs_per_iter_recording_on", num(per_iter)),
            ("events_in_8_iter_run", num(report.timeline.len() as f64)),
            ("series_rows_in_8_iter_run", num(report.series.len() as f64)),
        ])));
    }

    println!("== scale (ring, ADMM-AP — thread-per-node could not run these) ==");
    let mut scale_fields: Vec<(&str, Json)> = Vec::new();
    for n in [256usize, 1024] {
        let seq_name = format!("sequential {n} ring x {SCALE_ITERS} iters");
        let sharded_name = format!("sharded {n} ring x {SCALE_ITERS} iters");
        b.bench(&seq_name, || sequential_run(n, Topology::Ring, SCALE_ITERS));
        // capture the last benched run's report instead of paying for an
        // extra 1024-node run outside the timer
        let mut last_report = None;
        b.bench(&sharded_name, || {
            last_report = Some(sharded_run(n, Topology::Ring, SCALE_ITERS));
        });
        let report = last_report.expect("bench ran at least once");
        assert_eq!(report.iterations, SCALE_ITERS, "scale run must complete");
        let seq_ns = b.result(&seq_name).unwrap().mean_ns;
        let sharded_ns = b.result(&sharded_name).unwrap().mean_ns;
        // per-iteration coordination overhead at scale — the number the
        // ci.sh bench regression gate tracks commit over commit
        let overhead = (sharded_ns - seq_ns) / SCALE_ITERS as f64;
        println!("  n={n}: sharded overhead/iter {overhead:.0}ns over the \
                  sequential floor");
        let key = if n == 256 { "ring_256" } else { "ring_1024" };
        scale_fields.push((key, obj(vec![
            ("sequential_mean_ns", num(seq_ns)),
            ("sharded_mean_ns", num(sharded_ns)),
            ("coordination_overhead_sharded_ns_per_iter", num(overhead)),
            ("workers", num(report.workers as f64)),
            ("run", report.recorder.summary_json()),
        ])));
    }
    scale_fields.push(("legacy_note", s(
        "thread-per-node baseline skipped at scale: it needs one OS thread \
         plus per-neighbour Vec clones per node per iteration")));
    extra.push(("scale", obj(scale_fields)));

    println!("== persistent pool vs scoped spawns (ring 64, ADMM-AP) ==");
    const POOL_WORKERS: usize = 4;
    const POOL_ITERS: usize = 60;
    const SPAWN_RUNS: u64 = 5;
    let mut pool_fields: Vec<(&str, Json)> = Vec::new();
    for dim in [3usize, 32] {
        let cfg = |exec| ShardedConfig {
            scheme: SchemeKind::Ap,
            tol: 0.0,
            max_iters: POOL_ITERS,
            workers: POOL_WORKERS,
            exec,
            ..Default::default()
        };
        let factory = quad_problem_factory(64, dim, 9);
        let pool_runner =
            ShardedRunner::new(Topology::Ring.build(64).unwrap(), cfg(ExecMode::Pool));
        let scoped_runner =
            ShardedRunner::new(Topology::Ring.build(64).unwrap(), cfg(ExecMode::Scoped));

        // spawn accounting over a fixed run count, outside the timed loop:
        // the pool pays its workers once per runner lifetime, the scoped
        // baseline pays them again on every run
        let before = threads_spawned();
        for _ in 0..SPAWN_RUNS {
            black_box(pool_runner.run(factory.clone()).unwrap());
        }
        let pool_spawns = threads_spawned() - before;
        let before = threads_spawned();
        for _ in 0..SPAWN_RUNS {
            black_box(scoped_runner.run(factory.clone()).unwrap());
        }
        let scoped_spawns = threads_spawned() - before;
        assert!(pool_spawns <= POOL_WORKERS as u64,
                "pool spawns must be O(workers) per runner, got {pool_spawns}");
        assert_eq!(scoped_spawns, SPAWN_RUNS * POOL_WORKERS as u64,
                   "scoped baseline spawns one thread per worker per run");

        let pool_name = format!("pool dim {dim} ring 64 x {POOL_ITERS} iters");
        let scoped_name = format!("scoped dim {dim} ring 64 x {POOL_ITERS} iters");
        b.bench(&pool_name, || {
            black_box(pool_runner.run(factory.clone()).unwrap());
        });
        b.bench(&scoped_name, || {
            black_box(scoped_runner.run(factory.clone()).unwrap());
        });
        let pool_ns = b.result(&pool_name).unwrap().mean_ns / POOL_ITERS as f64;
        let scoped_ns = b.result(&scoped_name).unwrap().mean_ns / POOL_ITERS as f64;
        println!("  dim={dim}: pool {pool_ns:.0}ns/iter vs scoped {scoped_ns:.0}ns/iter \
                  ({}); spawns over {SPAWN_RUNS} runs: pool {pool_spawns}, \
                  scoped {scoped_spawns}",
                 if pool_ns <= scoped_ns { "pool wins" } else { "scoped wins" });
        let key = if dim == 3 { "dim_3" } else { "dim_32" };
        pool_fields.push((key, obj(vec![
            ("pool_ns_per_iter", num(pool_ns)),
            ("scoped_ns_per_iter", num(scoped_ns)),
            ("pool_win", Json::Bool(pool_ns <= scoped_ns)),
            ("threads_spawned_pool", num(pool_spawns as f64)),
            ("threads_spawned_scoped", num(scoped_spawns as f64)),
        ])));
    }
    pool_fields.push(("workers", num(POOL_WORKERS as f64)));
    pool_fields.push(("spawn_runs", num(SPAWN_RUNS as f64)));
    pool_fields.push(("crossover_note", s(
        "spawn amortization dominates at dim 3 where solves are cheap; at \
         dim 32 the solve cost hides synchronization and the two modes \
         converge — the crossover sits between those dims")));
    extra.push(("pool", obj(pool_fields)));

    let path = b.write_json("coordinator", extra).expect("write bench json");
    println!("wrote {}", path.display());
}

/// Bench-only replica of the thread-per-node mpsc runtime this repo used
/// before the sharded worker pool — one actor thread per node, `Vec`
/// clones per neighbour per iteration, HashMap staging for out-of-order
/// delivery, a stats channel into an aggregating leader. Kept verbatim
/// (including its per-element `/ n` global-mean pass) as the measurement
/// control; do not "optimize" it.
mod legacy {
    use std::collections::HashMap;
    use std::sync::mpsc::{channel, Receiver, Sender};

    use fadmm::consensus::solvers::QuadraticNode;
    use fadmm::consensus::LocalSolver;
    use fadmm::graph::{NodeId, Topology};
    use fadmm::penalty::{make_scheme, NodeObservation, SchemeKind, SchemeParams};
    use fadmm::util::rng::Pcg;

    #[derive(Clone)]
    struct Broadcast {
        from: NodeId,
        t: usize,
        theta: Vec<f64>,
        eta_to_receiver: f64,
    }

    struct StatsMsg {
        from: NodeId,
        f_self: f64,
        primal: f64,
        dual: f64,
        eta_sum: f64,
        eta_count: usize,
        theta: Vec<f64>,
    }

    #[derive(Clone, Copy)]
    struct Verdict {
        stop: bool,
        global_primal: f64,
        global_dual: f64,
    }

    pub fn run(n: usize, topo: Topology, max_iters: usize) -> Vec<Vec<f64>> {
        let graph = topo.build(n).unwrap();
        let scheme = SchemeKind::Ap;
        let params = SchemeParams::default();

        let mut bcast_tx: Vec<Sender<Broadcast>> = Vec::with_capacity(n);
        let mut bcast_rx: Vec<Option<Receiver<Broadcast>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            bcast_tx.push(tx);
            bcast_rx.push(Some(rx));
        }
        let (stats_tx, stats_rx) = channel::<StatsMsg>();
        let mut verdict_tx: Vec<Sender<Verdict>> = Vec::with_capacity(n);
        let mut verdict_rx: Vec<Option<Receiver<Verdict>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            verdict_tx.push(tx);
            verdict_rx.push(Some(rx));
        }

        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let neighbors: Vec<NodeId> = graph.neighbors(i).to_vec();
            let nb_senders: Vec<Sender<Broadcast>> =
                neighbors.iter().map(|&j| bcast_tx[j].clone()).collect();
            let my_rx = bcast_rx[i].take().unwrap();
            let my_verdicts = verdict_rx[i].take().unwrap();
            let stats = stats_tx.clone();
            handles.push(std::thread::spawn(move || {
                node_main(i, scheme, params, max_iters, neighbors, nb_senders,
                          my_rx, my_verdicts, stats)
            }));
        }
        drop(stats_tx);

        // leader: aggregate per-iteration stats, broadcast the verdict
        let mut gmean_prev: Option<Vec<f64>> = None;
        for t in 0..max_iters {
            let mut pending: Vec<Option<StatsMsg>> = (0..n).map(|_| None).collect();
            let mut received = 0;
            while received < n {
                let msg = stats_rx.recv().expect("node died");
                if pending[msg.from].replace(msg).is_none() {
                    received += 1;
                }
            }
            let stats: Vec<StatsMsg> = pending.into_iter().map(|m| m.unwrap()).collect();
            let _objective: f64 = stats.iter().map(|m| m.f_self).sum();
            let _max_primal = stats.iter().map(|m| m.primal).fold(0.0, f64::max);
            let _max_dual = stats.iter().map(|m| m.dual).fold(0.0, f64::max);
            let _eta_mean = {
                let cnt: usize = stats.iter().map(|m| m.eta_count).sum();
                if cnt == 0 { 0.0 } else {
                    stats.iter().map(|m| m.eta_sum).sum::<f64>() / cnt as f64
                }
            };
            let dim = stats[0].theta.len();
            let mut gmean = vec![0.0; dim];
            for m in &stats {
                for k in 0..dim {
                    gmean[k] += m.theta[k] / n as f64; // the old per-element /n
                }
            }
            let mut gr2 = 0.0;
            for m in &stats {
                for k in 0..dim {
                    let d = m.theta[k] - gmean[k];
                    gr2 += d * d;
                }
            }
            let gs2 = match &gmean_prev {
                Some(prev) => gmean
                    .iter()
                    .zip(prev)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>(),
                None => f64::INFINITY,
            };
            let global_dual = if gs2.is_finite() {
                params.eta0 * (n as f64).sqrt() * gs2.sqrt()
            } else {
                f64::INFINITY
            };
            gmean_prev = Some(gmean);
            let verdict = Verdict {
                stop: t + 1 == max_iters,
                global_primal: gr2.sqrt(),
                global_dual,
            };
            for tx in &verdict_tx {
                let _ = tx.send(verdict);
            }
        }

        let mut thetas: Vec<Vec<f64>> = vec![Vec::new(); n];
        for h in handles {
            let (id, theta) = h.join().expect("node panicked");
            thetas[id] = theta;
        }
        thetas
    }

    #[allow(clippy::too_many_arguments)]
    fn node_main(
        id: NodeId,
        scheme_kind: SchemeKind,
        params: SchemeParams,
        max_iters: usize,
        neighbors: Vec<NodeId>,
        nb_senders: Vec<Sender<Broadcast>>,
        inbox: Receiver<Broadcast>,
        verdicts: Receiver<Verdict>,
        stats: Sender<StatsMsg>,
    ) -> (NodeId, Vec<f64>) {
        let mut rng = Pcg::seed(3 + id as u64);
        let mut solver = QuadraticNode::random(super::DIM, &mut rng);
        let dim = solver.dim();
        let deg = neighbors.len();
        let mut init_rng = Pcg::new(0, id as u64 + 1);
        let mut theta = solver.initial_param(&mut init_rng);
        let mut lambda = vec![0.0; dim];
        let mut etas = vec![params.eta0; deg];
        let mut scheme = make_scheme(scheme_kind, params, deg);
        let mut f_self_prev = f64::INFINITY;
        let mut nbr_mean_prev = vec![0.0; dim];

        let slot_of: HashMap<NodeId, usize> =
            neighbors.iter().enumerate().map(|(s, &j)| (j, s)).collect();
        // out-of-order broadcast staging: tag → slot → (theta, eta)
        let mut pending: HashMap<usize, Vec<Option<(Vec<f64>, f64)>>> = HashMap::new();
        let mut known: Vec<Vec<f64>> = vec![Vec::new(); deg];
        let mut eta_in: Vec<f64> = vec![params.eta0; deg];

        let collect = |tag: usize,
                       pending: &mut HashMap<usize, Vec<Option<(Vec<f64>, f64)>>>,
                       known: &mut Vec<Vec<f64>>,
                       eta_in: &mut Vec<f64>| {
            loop {
                let entry = pending.entry(tag).or_insert_with(|| vec![None; deg]);
                if entry.iter().all(Option::is_some) {
                    let entry = pending.remove(&tag).unwrap();
                    for (slot, item) in entry.into_iter().enumerate() {
                        let (th, eta) = item.unwrap();
                        known[slot] = th;
                        eta_in[slot] = eta;
                    }
                    return;
                }
                match inbox.recv() {
                    Ok(msg) => {
                        let slot = slot_of[&msg.from];
                        pending
                            .entry(msg.t)
                            .or_insert_with(|| vec![None; deg])[slot] =
                            Some((msg.theta, msg.eta_to_receiver));
                    }
                    Err(_) => return,
                }
            }
        };

        for (slot, tx) in nb_senders.iter().enumerate() {
            let _ = tx.send(Broadcast {
                from: id, t: 0, theta: theta.clone(), eta_to_receiver: etas[slot],
            });
        }
        collect(0, &mut pending, &mut known, &mut eta_in);

        for t in 0..max_iters {
            let eta_sum: f64 = etas.iter().sum();
            let mut eta_wsum = vec![0.0; dim];
            for slot in 0..deg {
                let e = etas[slot];
                for k in 0..dim {
                    eta_wsum[k] += e * (theta[k] + known[slot][k]);
                }
            }
            theta = solver.solve(&theta, &lambda, eta_sum, &eta_wsum);

            for (slot, tx) in nb_senders.iter().enumerate() {
                let _ = tx.send(Broadcast {
                    from: id, t: t + 1, theta: theta.clone(),
                    eta_to_receiver: etas[slot],
                });
            }
            collect(t + 1, &mut pending, &mut known, &mut eta_in);

            for slot in 0..deg {
                let eta_bar = 0.5 * (etas[slot] + eta_in[slot]);
                for k in 0..dim {
                    lambda[k] += 0.5 * eta_bar * (theta[k] - known[slot][k]);
                }
            }

            let mut nbr_mean = vec![0.0; dim];
            for slot in 0..deg {
                for k in 0..dim {
                    nbr_mean[k] += known[slot][k] / deg.max(1) as f64;
                }
            }
            let eta_bar_node = eta_sum / deg.max(1) as f64;
            let mut r2 = 0.0;
            let mut s2 = 0.0;
            for k in 0..dim {
                let r = theta[k] - nbr_mean[k];
                let sd = eta_bar_node * (nbr_mean[k] - nbr_mean_prev[k]);
                r2 += r * r;
                s2 += sd * sd;
            }
            nbr_mean_prev = nbr_mean;

            let f_self = solver.objective(&theta);
            let mut f_nb = vec![0.0; deg];
            if scheme.needs_neighbor_objectives() {
                let mut rho = vec![0.0; dim];
                for slot in 0..deg {
                    for k in 0..dim {
                        rho[k] = 0.5 * (theta[k] + known[slot][k]);
                    }
                    f_nb[slot] = solver.objective(&rho);
                }
            }

            let _ = stats.send(StatsMsg {
                from: id,
                f_self,
                primal: r2.sqrt(),
                dual: s2.sqrt(),
                eta_sum,
                eta_count: deg,
                theta: theta.clone(),
            });
            let verdict = match verdicts.recv() {
                Ok(v) => v,
                Err(_) => break,
            };
            if verdict.stop {
                break;
            }

            let obs = NodeObservation {
                t,
                primal_norm: r2.sqrt(),
                dual_norm: s2.sqrt(),
                global_primal: verdict.global_primal,
                global_dual: verdict.global_dual,
                f_self,
                f_self_prev,
                f_neighbors: &f_nb,
                live: None,
            };
            scheme.update(&obs, &mut etas);
            f_self_prev = f_self;
        }
        (id, theta)
    }
}
