//! L3 coordination overhead: sequential engine vs threaded actors on the
//! same quadratic consensus problem (the compute is trivial, so this
//! isolates messaging/synchronization cost per iteration).

use std::sync::Arc;

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::consensus::{Engine, EngineConfig};
use fadmm::coordinator::{ThreadedConfig, ThreadedRunner};
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::util::bench::{black_box, Bencher};
use fadmm::util::rng::Pcg;

const ITERS: usize = 200;

fn main() {
    let mut b = Bencher::from_env();
    for n in [8usize, 20] {
        b.bench(&format!("sequential {n} nodes × {ITERS} iters"), || {
            let mut rng = Pcg::seed(3);
            let nodes: Vec<QuadraticNode> =
                (0..n).map(|_| QuadraticNode::random(4, &mut rng)).collect();
            let mut engine = Engine::new(Topology::Complete.build(n).unwrap(), nodes,
                                         EngineConfig {
                                             scheme: SchemeKind::Ap,
                                             tol: 0.0,
                                             max_iters: ITERS,
                                             ..Default::default()
                                         });
            black_box(engine.run());
        });
        b.bench(&format!("threaded   {n} nodes × {ITERS} iters"), || {
            let runner = ThreadedRunner::new(Topology::Complete.build(n).unwrap(),
                                             ThreadedConfig {
                                                 scheme: SchemeKind::Ap,
                                                 tol: 0.0,
                                                 max_iters: ITERS,
                                                 ..Default::default()
                                             });
            let report = runner
                .run(Arc::new(|i| {
                    let mut rng = Pcg::seed(3 + i as u64);
                    QuadraticNode::random(4, &mut rng)
                }), |_, _| 0.0)
                .unwrap();
            black_box(report);
        });
    }
}
