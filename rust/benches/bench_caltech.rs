//! E3 end-to-end bench: full runs of one turntable object per setting
//! (the figure regenerator's unit of work), native backend.

use fadmm::data::turntable::TurntableSpec;
use fadmm::dppca::InitStrategy;
use fadmm::experiments::caltech::SETTINGS;
use fadmm::experiments::common::{run_dppca, BackendChoice, DppcaSpec};
use fadmm::penalty::{SchemeKind, SchemeParams};
use fadmm::sfm;
use fadmm::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::from_env();
    let object = TurntableSpec::default().generate("Standing", 42);
    let data = sfm::ppca_input(&object.measurements);
    let (baseline, _) = sfm::svd_structure(&object.measurements).unwrap();
    let blocks = sfm::split_frames(&data, object.frames, 5);
    let backend = BackendChoice::Native.build().unwrap();

    for setting in SETTINGS {
        for scheme in [SchemeKind::Fixed, SchemeKind::Nap] {
            b.bench(
                &format!("caltech Standing {}/tmax{} {}", setting.topo.name(),
                         setting.t_max, scheme.name()),
                || {
                    let mut spec = DppcaSpec::new(
                        blocks.clone(), 12, 3, setting.topo.build(5).unwrap(), scheme);
                    spec.params = SchemeParams { t_max: setting.t_max, ..Default::default() };
                    spec.init = InitStrategy::LocalPca;
                    spec.max_iters = 200;
                    spec.reference = Some(&baseline);
                    black_box(run_dppca(&spec, backend.clone()).unwrap());
                },
            );
        }
    }
}
