//! Simulated-network runtime cost: wall-clock of the discrete-event loop,
//! plus the two scenario-level metrics the ROADMAP tracks — rounds to
//! consensus and virtual time — under a clean link vs 10% loss. Writes
//! the machine-readable `BENCH_net.json` (same layout contract as
//! `BENCH_coordinator.json`: a `results` array from the Bencher and a
//! derived `scenario` object for gates/dashboards).

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::experiments::common::quad_problem;
use fadmm::net::{AsyncRunner, FaultPlan, LinkModel, NetConfig};
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::util::bench::{black_box, Bencher};
use fadmm::util::json::{num, obj, s, Json};

const N: usize = 16;
const DIM: usize = 3;

fn lossy_plan(loss: f64) -> FaultPlan {
    FaultPlan {
        link: LinkModel { base: 2, jitter: 4, loss, dup: 0.02 },
        ..FaultPlan::none()
    }
}

fn run_once(scheme: SchemeKind, plan: FaultPlan, tol: f64, max_iters: usize)
            -> fadmm::net::NetReport {
    let solvers: Vec<QuadraticNode> = quad_problem(N, DIM, 77);
    let runner = AsyncRunner::new(
        Topology::Ring.build(N).unwrap(),
        solvers,
        NetConfig {
            scheme,
            tol,
            max_iters,
            seed: 5,
            max_staleness: 1,
            silence_timeout: 16,
            tracing: false,
            ..Default::default()
        },
        plan,
    );
    runner.run()
}

fn main() {
    let mut b = Bencher::from_env();
    // keyed by owned strings; borrowed at the single obj() call below
    let mut scenario_fields: Vec<(String, Json)> = Vec::new();

    println!("== event-loop wall cost (ring {N}, ADMM-AP, fixed 120 rounds) ==");
    b.bench("async zero-fault 120 rounds", || {
        black_box(run_once(SchemeKind::Ap, FaultPlan::none(), 0.0, 120));
    });
    b.bench("async 10% loss 120 rounds", || {
        black_box(run_once(SchemeKind::Ap, lossy_plan(0.10), 0.0, 120));
    });

    println!("== rounds-to-consensus and virtual time (tol 1e-6) ==");
    // deterministic single runs — these are scenario metrics, not timing
    for (name, loss) in [("clean", 0.0f64), ("loss10", 0.10)] {
        for scheme in [SchemeKind::Fixed, SchemeKind::Ap, SchemeKind::Nap,
                       SchemeKind::VpNap] {
            let plan = if loss > 0.0 { lossy_plan(loss) } else { FaultPlan::none() };
            let report = run_once(scheme, plan, 1e-6, 800);
            let last_primal = report
                .recorder
                .stats
                .last()
                .map(|st| st.max_primal)
                .unwrap_or(f64::NAN);
            println!(
                "{name:<8} {:<12} rounds {:>4} vtime {:>7} dropped {:>5} \
                 stale {:>6} primal {:.3e}",
                scheme.name(), report.iterations, report.virtual_time,
                report.counters.dropped_total(), report.counters.stale_reads,
                last_primal,
            );
            let key = format!("{name}_{}", scheme.name());
            scenario_fields.push((
                key,
                obj(vec![
                    ("rounds", num(report.iterations as f64)),
                    ("virtual_time", num(report.virtual_time as f64)),
                    ("converged", num(if report.converged { 1.0 } else { 0.0 })),
                    ("final_primal", num(last_primal)),
                    ("dropped", num(report.counters.dropped_total() as f64)),
                    ("stale_reads", num(report.counters.stale_reads as f64)),
                    ("counters", report.counters.summary_json()),
                ]),
            ));
        }
    }

    let scenario = obj(scenario_fields
        .iter()
        .map(|(k, v)| (k.as_str(), v.clone()))
        .collect());
    let extra = vec![
        ("nodes", num(N as f64)),
        ("dim", num(DIM as f64)),
        ("topology", s("ring")),
        ("scenario", scenario),
    ];
    match b.write_json("net", extra) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("bench_net: could not write JSON: {e}"),
    }
}
