//! Hot-path microbenchmarks: the per-node compute operations on both
//! backends, across the experiment shapes. This is the L1/L2-side profile
//! that drives EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench bench_node_update
//!     FADMM_BENCH_FAST=1 cargo bench   # CI smoke settings

use fadmm::dppca::PpcaParams;
use fadmm::linalg::Mat;
use fadmm::runtime::{Backend, NativeBackend};
use fadmm::util::bench::{black_box, Bencher};
use fadmm::util::rng::Pcg;

fn inputs(d: usize, m: usize, n: usize)
          -> (Mat, Vec<f64>, PpcaParams, PpcaParams, f64, PpcaParams) {
    let mut rng = Pcg::seed(1);
    let x = Mat::randn(d, n, &mut rng);
    let mask = vec![1.0; n];
    let params = PpcaParams { w: Mat::randn(d, m, &mut rng), mu: rng.normal_vec(d), a: 1.0 };
    let mult = PpcaParams::zeros(d, m);
    let eta_sum = 20.0;
    let eta_w = PpcaParams {
        w: params.w.scale(2.0 * eta_sum),
        mu: params.mu.iter().map(|v| 2.0 * eta_sum * v).collect(),
        a: 2.0 * eta_sum,
    };
    (x, mask, params, mult, eta_sum, eta_w)
}

fn bench_backend(b: &mut Bencher, label: &str, backend: &mut dyn Backend,
                 d: usize, m: usize, n: usize) {
    let (x, mask, params, mult, eta_sum, eta_w) = inputs(d, m, n);
    let mom = backend.moments(&x, &mask).unwrap();
    b.bench(&format!("{label}/moments d{d} n{n}"), || {
        black_box(backend.moments(&x, &mask).unwrap());
    });
    b.bench(&format!("{label}/node_update d{d} m{m}"), || {
        black_box(backend.node_update(&mom, &params, &mult, eta_sum, &eta_w).unwrap());
    });
    b.bench(&format!("{label}/objective d{d} m{m}"), || {
        black_box(backend.objective(&mom, &params).unwrap());
    });
    b.bench(&format!("{label}/estep_z d{d} m{m} n{n}"), || {
        black_box(backend.estep_z(&x, &mask, &params).unwrap());
    });
}

#[cfg(feature = "xla")]
fn bench_xla(b: &mut Bencher, shapes: &[(usize, usize, usize)]) {
    use fadmm::runtime::{Manifest, XlaBackend};
    if Manifest::default_dir().join("manifest.json").exists() {
        println!("== xla backend (PJRT, AOT artifacts) ==");
        let mut xla = XlaBackend::from_default_dir().expect("xla backend");
        for &(d, m, n) in shapes {
            xla.warmup(d, m, n).unwrap();
            bench_backend(b, "xla", &mut xla, d, m, n);
        }
    } else {
        println!("(xla backend skipped: run `make artifacts`)");
    }
}

#[cfg(not(feature = "xla"))]
fn bench_xla(_b: &mut Bencher, _shapes: &[(usize, usize, usize)]) {
    println!("(xla backend skipped: rebuild with --features xla + make artifacts)");
}

fn main() {
    let mut b = Bencher::from_env();
    let shapes = [(20usize, 5usize, 25usize), (120, 3, 12)];

    println!("== native backend ==");
    let mut native = NativeBackend::new();
    for (d, m, n) in shapes {
        bench_backend(&mut b, "native", &mut native, d, m, n);
    }

    bench_xla(&mut b, &shapes);

    let path = b.write_json("node_update", vec![]).expect("write bench json");
    println!("wrote {}", path.display());
}
