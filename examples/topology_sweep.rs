//! How graph connectivity shapes each scheme's convergence — a compact
//! reproduction of the paper's topology finding (§5.1: VP shines on
//! complete graphs, AP/NAP are the robust choice on weakly connected
//! ones), on fast pure-Rust quadratic consensus problems.
//!
//!     cargo run --release --example topology_sweep

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::consensus::{Engine, EngineConfig};
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::util::rng::Pcg;
use fadmm::util::stats;

fn iterations(topo: Topology, scheme: SchemeKind, seed: u64) -> usize {
    let mut rng = Pcg::seed(seed);
    let nodes: Vec<QuadraticNode> =
        (0..12).map(|_| QuadraticNode::random(4, &mut rng)).collect();
    let mut engine = Engine::new(topo.build(12).unwrap(), nodes, EngineConfig {
        scheme,
        tol: 1e-8,
        max_iters: 1000,
        seed,
        ..Default::default()
    });
    engine.run().iterations
}

fn main() {
    let topologies = [Topology::Complete, Topology::Cluster, Topology::Grid,
                      Topology::Ring, Topology::Chain];
    println!("median iterations to convergence (5 seeds, 12-node quadratic consensus)\n");
    print!("{:<12}", "scheme");
    for t in topologies {
        print!("{:>10}", t.name());
    }
    println!();
    for scheme in SchemeKind::ALL {
        print!("{:<12}", scheme.name());
        for topo in topologies {
            if topo == Topology::Grid && 12usize.isqrt().pow(2) != 12 {
                // grid needs a square count; substitute 16 nodes
            }
            let med = if topo == Topology::Grid {
                // grid needs a perfect square — run 16 nodes there
                let runs: Vec<f64> = (0..5)
                    .map(|s| {
                        let mut rng = Pcg::seed(s);
                        let nodes: Vec<QuadraticNode> =
                            (0..16).map(|_| QuadraticNode::random(4, &mut rng)).collect();
                        let mut engine = Engine::new(
                            Topology::Grid.build(16).unwrap(), nodes,
                            EngineConfig { scheme, tol: 1e-8, max_iters: 1000,
                                           seed: s, ..Default::default() });
                        engine.run().iterations as f64
                    })
                    .collect();
                stats::median(&runs)
            } else {
                let runs: Vec<f64> =
                    (0..5).map(|s| iterations(topo, scheme, s) as f64).collect();
                stats::median(&runs)
            };
            print!("{:>10.0}", med);
        }
        println!();
    }
    println!("\n(diameter: complete=1, cluster=3, grid=6, ring=6, chain=11)");
}
