//! Distributed sparse regression (consensus lasso) across penalty schemes.
//!
//! Twelve nodes each observe 25 noisy measurements of a 10-dim signal with
//! only 3 active coefficients; the network jointly recovers the sparse
//! support. Demonstrates a non-smooth f_i (soft-thresholding inner solver)
//! under every penalty scheme on a weakly connected (cluster) graph.
//!
//!     cargo run --release --example lasso_consensus

use fadmm::consensus::solvers::LassoNode;
use fadmm::consensus::{Engine, EngineConfig};
use fadmm::graph::Topology;
use fadmm::linalg::Mat;
use fadmm::penalty::SchemeKind;
use fadmm::util::rng::Pcg;

fn main() {
    let dim = 10;
    let mut signal = vec![0.0; dim];
    signal[1] = 2.0;
    signal[4] = -3.0;
    signal[7] = 1.5;

    let graph = Topology::Cluster.build(12).expect("cluster(12)");
    println!("consensus lasso: 12 nodes (two cliques + bridge), 10-dim, 3-sparse\n");
    println!("{:<12} {:>6} {:>10} {:>22}", "scheme", "iters", "converged",
             "support recovered?");

    for scheme in SchemeKind::PAPER {
        let mut rng = Pcg::seed(7);
        let nodes: Vec<LassoNode> = (0..12)
            .map(|_| {
                let a = Mat::randn(25, dim, &mut rng);
                let b: Vec<f64> = (0..25)
                    .map(|r| {
                        a.row(r).iter().zip(&signal).map(|(x, t)| x * t).sum::<f64>()
                            + 0.1 * rng.normal()
                    })
                    .collect();
                LassoNode::new(a, b, 6.0)
            })
            .collect();
        let mut engine = Engine::new(graph.clone(), nodes, EngineConfig {
            scheme,
            tol: 1e-7,
            max_iters: 500,
            seed: 3,
            ..Default::default()
        });
        let report = engine.run();
        let theta = &report.thetas[0];
        let support_ok = (0..dim).all(|k| {
            let active = signal[k] != 0.0;
            let detected = theta[k].abs() > 0.3;
            active == detected
        });
        println!("{:<12} {:>6} {:>10} {:>22}", scheme.name(), report.iterations,
                 report.converged, if support_ok { "yes" } else { "NO" });
    }
}
