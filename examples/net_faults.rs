//! Asynchronous ADMM on an unreliable simulated network.
//!
//! Runs ADMM-NAP on a 12-node ring where 10% of messages drop, latency
//! jitters, one node joins mid-run over two bridge edges and another
//! leaves later — then prints the convergence story and the fault ledger.
//! Everything is seeded: run it twice and the event trace is identical.
//!
//!     cargo run --release --example net_faults

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::graph::Graph;
use fadmm::net::{AsyncRunner, ChurnEvent, FaultPlan, LinkModel, NetConfig};
use fadmm::penalty::SchemeKind;
use fadmm::util::rng::Pcg;

fn main() {
    let n = 12usize;
    // ring 0..11 plus a dormant bridge node 12 across the antipodes
    let mut edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    edges.push((n, 0));
    edges.push((n, n / 2));
    let graph = Graph::new(n + 1, &edges).expect("valid topology");

    let mut rng = Pcg::seed(42);
    let solvers: Vec<QuadraticNode> =
        (0..n + 1).map(|_| QuadraticNode::random(3, &mut rng)).collect();
    let opt = QuadraticNode::central_optimum(&solvers);

    let plan = FaultPlan {
        link: LinkModel { base: 2, jitter: 5, loss: 0.10, dup: 0.02 },
        partitions: vec![],
        churn: vec![
            ChurnEvent::Join { at: 300, node: n },
            ChurnEvent::Leave { at: 900, node: 3 },
        ],
        initially_dormant: vec![n],
    };
    let runner = AsyncRunner::new(graph, solvers, NetConfig {
        scheme: SchemeKind::Nap,
        tol: 1e-6,
        max_iters: 600,
        max_staleness: 1,
        silence_timeout: 16,
        ..Default::default()
    }, plan);
    let report = runner.run();

    println!("rounds folded     : {}", report.iterations);
    println!("converged         : {}", report.converged);
    println!("virtual time      : {} ticks", report.virtual_time);
    let c = &report.counters;
    println!("messages          : {} sent, {} delivered, {} dropped \
              ({} loss / {} dead), {} duplicated",
             c.sent, c.delivered, c.dropped_total(), c.dropped_loss,
             c.dropped_dead, c.duplicated);
    println!("staleness         : {} stale reads, {} forced fallbacks, \
              {} timeouts", c.stale_reads, c.fallback_reads, c.timeouts);
    println!("churn             : {} joins, {} leaves", c.joins, c.leaves);
    println!("trace length      : {} events (replayable)", report.trace.len());

    if let Some(last) = report.recorder.stats.last() {
        println!("final max primal  : {:.3e}", last.max_primal);
    }
    // distance of the survivors from the (full-set) central optimum — the
    // departed node's objective is gone, so survivors land near, not on,
    // the original optimum
    let mut worst = 0.0f64;
    for (i, th) in report.thetas.iter().enumerate() {
        if !report.live[i] {
            continue;
        }
        let d = th
            .iter()
            .zip(&opt)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        worst = worst.max(d);
    }
    println!("max ‖θ − θ*_full‖ : {worst:.3e} over live nodes");
}
