//! END-TO-END driver: distributed structure from motion through the full
//! three-layer stack.
//!
//! * L1/L2: the node update executes the AOT-lowered HLO artifacts
//!   (Pallas moments kernel + JAX EM/consensus step) via PJRT;
//! * L3: the Rust consensus engine with the paper's ADMM-NAP penalty
//!   scheduler coordinates five cameras on a ring network.
//!
//! Workload: a synthetic turntable object ("Standing", 120 tracked points
//! over 30 frames — the Caltech substitute, DESIGN.md §3). The run logs
//! the loss curve, reconstructs the 3-D structure from the latents, and
//! reports accuracy vs the centralized SVD baseline plus throughput.
//!
//! Requires `make artifacts`. Run:
//!     cargo run --release --example dppca_sfm

use std::time::Instant;

use fadmm::data::turntable::TurntableSpec;
use fadmm::experiments::common::{max_angle_vs_reference, run_dppca, DppcaSpec};
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::runtime::{shared, Backend, XlaBackend};
use fadmm::sfm;

fn main() -> fadmm::Result<()> {
    // ---- workload ----------------------------------------------------------
    let object = TurntableSpec::default().generate("Standing", 42);
    let data = sfm::ppca_input(&object.measurements);
    let (svd_baseline, svd_residual) = sfm::svd_structure(&object.measurements)?;
    let blocks = sfm::split_frames(&data, object.frames, 5);
    println!("object      : {} ({} points, {} frames)", object.name,
             object.structure.rows(), object.frames);
    println!("cameras     : 5 on a ring network, {} frame-rows each",
             blocks[0].cols());
    println!("svd baseline: rank-3 residual {svd_residual:.2e}\n");

    // ---- backend: AOT artifacts via PJRT ------------------------------------
    let mut xla = XlaBackend::from_default_dir()?;
    let t_compile = Instant::now();
    let compiled = xla.warmup(120, 3, 12)?;
    println!("compiled {compiled} HLO artifacts in {:.2}s (cached thereafter)",
             t_compile.elapsed().as_secs_f64());
    let backend = shared(xla);

    // ---- distributed optimization -------------------------------------------
    let mut spec = DppcaSpec::new(blocks, 12, 3,
                                  Topology::Ring.build(5)?, SchemeKind::Nap);
    spec.max_iters = 300;
    spec.init = fadmm::dppca::InitStrategy::LocalPca;
    spec.reference = Some(&svd_baseline);
    let t_run = Instant::now();
    let result = run_dppca(&spec, backend.clone())?;
    let secs = t_run.elapsed().as_secs_f64();

    println!("\niter  objective(Σ NLL)  max-angle(deg)  mean-eta");
    for s in result.recorder.stats.iter().step_by(10) {
        println!("{:>4}  {:>16.2}  {:>14.4}  {:>8.2}", s.iter, s.objective,
                 s.app_error, s.mean_eta);
    }
    let last = result.recorder.stats.last().unwrap();
    println!("{:>4}  {:>16.2}  {:>14.4}  {:>8.2}", last.iter, last.objective,
             last.app_error, last.mean_eta);

    // ---- structure extraction through the L1 estep kernel -------------------
    let cam0 = &result.params[0];
    println!("\nreconstructed structure: camera 0's W is {}x{} (= the 3-D points)",
             cam0.w.rows(), cam0.w.cols());
    let final_angle = max_angle_vs_reference(
        &result.params.iter().map(|p| p.flatten()).collect::<Vec<_>>(),
        120, 3, &svd_baseline);
    // latents = camera motion per frame-row, via the estep_z artifact
    let mut backend_ref = backend.borrow_mut();
    let motion = backend_ref.estep_z(
        &pad(&sfm::split_frames(&data, object.frames, 5)[0], 12), &mask(12, 12), cam0)?;
    drop(backend_ref);

    // ---- report --------------------------------------------------------------
    let iters = result.iterations;
    println!("\n== RESULT ==");
    println!("converged        : {} in {} iterations ({:.2}s, {:.1} iter/s)",
             result.converged, iters, secs, iters as f64 / secs);
    println!("structure error  : {final_angle:.4}° max subspace angle vs SVD");
    println!("camera motion    : {}x{} latent matrix extracted via estep_z kernel",
             motion.rows(), motion.cols());
    println!("noise precision  : a = {:.2} (per camera, consensus)", cam0.a);

    assert!(final_angle < 20.0, "structure error too large: {final_angle}°");
    println!("\nOK — full stack (Pallas kernel → JAX HLO → PJRT → Rust ADMM-NAP) verified");
    Ok(())
}

fn pad(x: &fadmm::linalg::Mat, n: usize) -> fadmm::linalg::Mat {
    let mut out = fadmm::linalg::Mat::zeros(x.rows(), n);
    for r in 0..x.rows() {
        out.row_mut(r)[..x.cols()].copy_from_slice(x.row(r));
    }
    out
}

fn mask(valid: usize, n: usize) -> Vec<f64> {
    (0..n).map(|k| f64::from(k < valid)).collect()
}
