//! Scale demo: 256 nodes on a ring, run by the sharded worker-pool
//! coordinator — a workload the original thread-per-node runtime could
//! not touch (it spawned one OS thread per node and heap-cloned every θ
//! per neighbour per iteration).
//!
//! Each node holds a private strongly convex quadratic; the network
//! agrees on the global minimizer through consensus ADMM with the
//! paper's ADMM-AP adaptive penalty. The sharded runner exchanges
//! parameters through a zero-copy double-buffered arena — solvers write
//! θ^{t+1} straight into it via `solve_into`, nodes are RCM-relabeled so
//! neighbours co-locate within a shard, and a steady-state iteration
//! performs zero heap allocations — so the per-node cost is just the
//! local solve plus three pool barriers per iteration.
//!
//!     cargo run --release --example sharded_ring

use std::sync::Arc;
use std::time::Instant;

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::coordinator::{ShardedConfig, ShardedRunner, SolverFactory};
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::util::rng::Pcg;

const NODES: usize = 256;
const DIM: usize = 6;

fn main() {
    let graph = Topology::Ring.build(NODES).expect("ring(256)");
    println!("sharded consensus: {NODES} nodes, ring topology, {DIM}-dim parameter");

    // the factory re-derives node i's problem inside whichever worker owns
    // it — nothing but the closure crosses threads
    let factory: SolverFactory<QuadraticNode> = Arc::new(|i| {
        let mut rng = Pcg::seed(1000 + i as u64);
        QuadraticNode::random(DIM, &mut rng)
    });
    // central optimum for reference (the test oracle at demo scale)
    let nodes: Vec<QuadraticNode> = (0..NODES)
        .map(|i| {
            let mut rng = Pcg::seed(1000 + i as u64);
            QuadraticNode::random(DIM, &mut rng)
        })
        .collect();
    let optimum = QuadraticNode::central_optimum(&nodes);

    let runner = ShardedRunner::new(graph, ShardedConfig {
        scheme: SchemeKind::Ap,
        tol: 1e-9,
        max_iters: 4000,
        ..Default::default()
    });
    println!("worker pool : {} workers ({} nodes per shard on average)\n",
             runner.workers(), NODES / runner.workers().max(1));

    let t0 = Instant::now();
    let report = runner.run(factory).expect("sharded run");
    let secs = t0.elapsed().as_secs_f64();

    let err = report
        .thetas
        .iter()
        .map(|th| {
            th.iter()
                .zip(&optimum)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt()
        })
        .fold(0.0f64, f64::max);

    println!("converged    : {} in {} iterations ({:.2}s, {:.0} iter/s)",
             report.converged, report.iterations, secs,
             report.iterations as f64 / secs);
    println!("max distance : {err:.3e} to the centralized optimum");
    println!("\nA ring of 256 nodes has diameter 128, so information needs many");
    println!("hops — exactly the regime where the paper's adaptive per-edge");
    println!("penalties (and a runtime that scales past a few dozen nodes)");
    println!("start to matter.");
}
