//! Hybrid cluster runtime in a few lines: four simulated machines, each
//! running a sharded worker pool over its slice of a 16-node ring,
//! exchanging boundary state over a lossy simulated network, with the
//! global fold carried by a spanning-tree reduce vs a push-sum gossip
//! all-reduce.
//!
//!     cargo run --release --example cluster_machines

use fadmm::cluster::{ClusterConfig, ClusterRunner, CollectiveKind};
use fadmm::consensus::solvers::QuadraticNode;
use fadmm::coordinator::{ShardedConfig, ShardedRunner, SolverFactory};
use fadmm::experiments::common::quad_problem_factory;
use fadmm::graph::Topology;
use fadmm::net::{FaultPlan, LinkModel};
use fadmm::penalty::SchemeKind;

const N: usize = 16;
const DIM: usize = 3;

fn factory() -> SolverFactory<QuadraticNode> {
    quad_problem_factory(N, DIM, 42)
}

fn main() {
    // the omniscient-fold oracle: one box, four worker shards
    let oracle = ShardedRunner::new(
        Topology::Ring.build(N).unwrap(),
        ShardedConfig { scheme: SchemeKind::Nap, tol: 1e-6, max_iters: 600,
                        workers: 4, ..Default::default() },
    )
    .run(factory())
    .unwrap();
    println!("oracle (sharded pool, omniscient fold): {} rounds", oracle.iterations);

    for loss in [0.0, 0.10] {
        for collective in CollectiveKind::ALL {
            let plan = if loss > 0.0 {
                FaultPlan {
                    link: LinkModel { base: 2, jitter: 4, loss, dup: 0.02 },
                    ..FaultPlan::none()
                }
            } else {
                FaultPlan::none()
            };
            let report = ClusterRunner::new(
                Topology::Ring.build(N).unwrap(),
                ClusterConfig {
                    scheme: SchemeKind::Nap,
                    tol: 1e-6,
                    max_iters: 600,
                    machines: 4,
                    workers: 1,
                    collective,
                    max_staleness: if loss > 0.0 { 1 } else { 0 },
                    silence_timeout: 16,
                    collective_timeout: 24,
                    tracing: false,
                    ..Default::default()
                },
                plan,
                factory(),
            )
            .unwrap()
            .run();
            let last = report.recorder.stats.last().unwrap();
            println!(
                "loss {:>4.0}% {:<7} {} machines: {} rounds (extra {:+}), \
                 vtime {}, dropped {}, final primal {:.2e}",
                loss * 100.0,
                collective.name(),
                report.machines,
                report.iterations,
                report.iterations as i64 - oracle.iterations as i64,
                report.virtual_time,
                report.counters.dropped_total(),
                last.max_primal,
            );
        }
    }
}
