//! Quickstart: distributed least squares with an adaptive penalty.
//!
//! Eight nodes each hold a slice of a regression problem and cooperate
//! over a ring to find the global fit — no data pooling, no center node.
//! We run the fixed-penalty baseline and the paper's ADMM-AP scheme and
//! compare iterations to convergence.
//!
//!     cargo run --release --example quickstart

use fadmm::consensus::solvers::LeastSquaresNode;
use fadmm::consensus::{Engine, EngineConfig};
use fadmm::graph::Topology;
use fadmm::linalg::Mat;
use fadmm::penalty::SchemeKind;
use fadmm::util::rng::Pcg;

fn make_nodes(n_nodes: usize, rows: usize, dim: usize, seed: u64)
              -> (Vec<LeastSquaresNode>, Vec<f64>) {
    let mut rng = Pcg::seed(seed);
    let theta_true = rng.normal_vec(dim);
    let nodes = (0..n_nodes)
        .map(|_| {
            let a = Mat::randn(rows, dim, &mut rng);
            let b: Vec<f64> = (0..rows)
                .map(|r| {
                    a.row(r).iter().zip(&theta_true).map(|(x, t)| x * t).sum::<f64>()
                        + 0.05 * rng.normal()
                })
                .collect();
            LeastSquaresNode::new(a, b)
        })
        .collect();
    (nodes, theta_true)
}

fn main() {
    let graph = Topology::Ring.build(8).expect("ring(8)");
    println!("distributed least squares: 8 nodes, ring topology, 5-dim parameter\n");

    for scheme in [SchemeKind::Fixed, SchemeKind::Ap, SchemeKind::Nap] {
        let (nodes, theta_true) = make_nodes(8, 24, 5, 42);
        let mut engine = Engine::new(graph.clone(), nodes, EngineConfig {
            scheme,
            tol: 1e-8,
            max_iters: 600,
            seed: 1,
            ..Default::default()
        });
        let report = engine.run();
        // worst-node distance to the true parameter
        let err = report
            .thetas
            .iter()
            .map(|th| {
                th.iter()
                    .zip(&theta_true)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .fold(0.0f64, f64::max);
        println!(
            "{:<12} converged={} iterations={:<4} max dist to θ* = {:.4}",
            scheme.name(), report.converged, report.iterations, err
        );
    }
    println!("\nADMM-AP / ADMM-NAP need no τ tuning — the penalty adapts from");
    println!("each node's local objective (paper eq. 6-9).");
}
