"""Small SPD inverse / log-determinant in pure jnp.

`jnp.linalg.inv` / `cholesky` lower to LAPACK *custom calls* on CPU, which
the pinned runtime (xla_extension 0.5.1 behind the Rust `xla` crate) does
not register — the compiled executable would die at run time. Every matrix
we ever invert is a tiny well-conditioned SPD system (M×M with M ∈ {2,3,5},
`WᵀW + a⁻¹I` or the M-step normalizer), so an unrolled Gauss-Jordan sweep
without pivoting lowers to plain HLO ops and is numerically safe.

The trip count is the static dimension → fully unrolled at trace time.
"""

from __future__ import annotations

import jax.numpy as jnp


def inv_and_logdet_spd(a: jnp.ndarray):
    """Inverse and log-determinant of a small SPD matrix.

    Gauss-Jordan without pivoting; valid for SPD inputs (pivots equal the
    Cholesky pivots squared-scaled and stay positive).

    Returns:
      (a_inv, logdet) with ``a_inv`` of the same shape/dtype as ``a``.
    """
    m = a.shape[0]
    aug = jnp.concatenate([a, jnp.eye(m, dtype=a.dtype)], axis=1)
    logdet = jnp.zeros((), dtype=a.dtype)
    for k in range(m):
        piv = aug[k, k]
        logdet = logdet + jnp.log(piv)
        row = aug[k] / piv
        # eliminate column k from every row, then restore the pivot row
        aug = aug - jnp.outer(aug[:, k], row)
        aug = aug.at[k].set(row)
    return aug[:, m:], logdet


def inv_spd(a: jnp.ndarray) -> jnp.ndarray:
    """Inverse of a small SPD matrix (see `inv_and_logdet_spd`)."""
    inv, _ = inv_and_logdet_spd(a)
    return inv
