"""AOT lowering: JAX (L2 + L1) → HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax ≥0.5
emits protos with 64-bit instruction ids which the pinned xla_extension
0.5.1 behind the Rust `xla` crate rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True`` — the Rust side unwraps with ``to_tuple*``.

Usage:  python -m compile.aot --out-dir ../artifacts
Emits one ``<name>.hlo.txt`` per (kind, shape) pair plus ``manifest.json``
describing the calling convention of every artifact (consumed by
``rust/src/runtime/artifact.rs``).
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels.estep import estep_z  # noqa: E402
from .kernels.moments import moments  # noqa: E402
from .shapes import CONFIGS, unique_dm, unique_dn  # noqa: E402

DTYPE = jnp.float64


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _moments_specs(d, n):
    return [_spec(d, n), _spec(n)]


def _node_update_specs(d, m):
    # n, sx, sxx, w, mu, a, lam, gam, beta, eta_sum, eta_w_w, eta_w_mu, eta_w_a
    return [_spec(), _spec(d), _spec(d, d), _spec(d, m), _spec(d), _spec(),
            _spec(d, m), _spec(d), _spec(), _spec(), _spec(d, m), _spec(d),
            _spec()]


def _node_update_direct_specs(d, m, n):
    return [_spec(d, n), _spec(n), _spec(d, m), _spec(d), _spec(),
            _spec(d, m), _spec(d), _spec(), _spec(), _spec(d, m), _spec(d),
            _spec()]


def _objective_specs(d, m):
    return [_spec(), _spec(d), _spec(d, d), _spec(d, m), _spec(d), _spec()]


def _objective_batch_specs(d, m):
    b = model.OBJECTIVE_BATCH
    return [_spec(), _spec(d), _spec(d, d), _spec(b, d, m), _spec(b, d),
            _spec(b)]


def _estep_specs(d, m, n):
    return [_spec(d, n), _spec(n), _spec(d, m), _spec(d), _spec()]


def _tuple_wrap(fn):
    """Every artifact returns a tuple (single outputs become 1-tuples)."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def build_plan():
    """All (name, fn, arg_specs, meta) lowering targets."""
    plan = []
    for d, m in unique_dm():
        plan.append((
            f"node_update_d{d}_m{m}", _tuple_wrap(model.node_update_from_moments),
            _node_update_specs(d, m),
            dict(kind="node_update", d=d, m=m, n=0),
        ))
        plan.append((
            f"objective_d{d}_m{m}", _tuple_wrap(model.objective_from_moments),
            _objective_specs(d, m),
            dict(kind="objective", d=d, m=m, n=0),
        ))
        plan.append((
            f"objective_batch_d{d}_m{m}",
            _tuple_wrap(model.objective_batch_from_moments),
            _objective_batch_specs(d, m),
            dict(kind="objective_batch", d=d, m=m, n=model.OBJECTIVE_BATCH),
        ))
    for d, n in unique_dn():
        plan.append((
            f"moments_d{d}_n{n}", _tuple_wrap(moments),
            _moments_specs(d, n),
            dict(kind="moments", d=d, m=0, n=n),
        ))
    for cfg in CONFIGS:
        d, m, n = cfg.d, cfg.m, cfg.n
        plan.append((
            f"node_update_direct_d{d}_m{m}_n{n}",
            _tuple_wrap(model.node_update_direct),
            _node_update_direct_specs(d, m, n),
            dict(kind="node_update_direct", d=d, m=m, n=n),
        ))
        plan.append((
            f"estep_z_d{d}_m{m}_n{n}", _tuple_wrap(estep_z),
            _estep_specs(d, m, n),
            dict(kind="estep_z", d=d, m=m, n=n),
        ))
    return plan


def lower_all(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for name, fn, specs, meta in build_plan():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = [list(o.shape) for o in lowered.out_info]
        entries.append(dict(
            name=name, file=fname, num_inputs=len(specs),
            input_shapes=[list(s.shape) for s in specs],
            output_shapes=out_shapes, **meta,
        ))
        if verbose:
            print(f"  lowered {name:40s} ({len(text)} chars)")
    manifest = dict(version=1, dtype="f64", artifacts=entries)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(entries)} artifacts + manifest.json to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    lower_all(args.out_dir, verbose=not args.quiet)


if __name__ == "__main__":
    main()
