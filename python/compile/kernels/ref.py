"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must agree with its oracle to floating-point tolerance (enforced by
`python/tests/test_kernels.py`, swept over shapes and dtypes with
hypothesis). The Rust-side native backend re-implements the same math, so
the chain of evidence is  ref.py (jnp)  ==  Pallas kernel  ==  lowered HLO
==  rust `dppca::em`.
"""

from __future__ import annotations

import jax.numpy as jnp


def moments_ref(x: jnp.ndarray, mask: jnp.ndarray):
    """Masked raw moments of a D×N sample block.

    Returns (n, sx, sxx):
      n   = Σ_k m_k                (scalar)
      sx  = Σ_k m_k x_k            (D,)
      sxx = Σ_k m_k x_k x_kᵀ       (D, D)
    """
    xm = x * mask[None, :]
    n = jnp.sum(mask)
    sx = jnp.sum(xm, axis=1)
    sxx = xm @ x.T
    return n, sx, sxx


def estep_z_ref(x: jnp.ndarray, mask: jnp.ndarray, w: jnp.ndarray,
                mu: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Posterior means E[z_k] = M⁻¹Wᵀ(x_k − μ) for every masked sample.

    Returns an (M, N) matrix; masked-out columns are zero.
    """
    m = w.shape[1]
    mmat = w.T @ w + jnp.eye(m, dtype=x.dtype) / a
    minv = jnp.linalg.inv(mmat)
    centred = (x - mu[:, None]) * mask[None, :]
    return minv @ (w.T @ centred)
