"""L1 Pallas kernel: masked raw-moment accumulation.

This is the only place the optimizer ever touches the raw data matrix, and
therefore the O(N·D²) hot spot of the whole stack (everything downstream is
O(D²·M) on the accumulated moments — see DESIGN.md §1).

TPU shape of the kernel: the output moments (`sxx` is D×D) are *stationary*
in VMEM while X is streamed HBM→VMEM in (D × Tn) column tiles; each grid
step performs a rank-Tn update `sxx += (x·m) xᵀ` on the MXU plus two VPU
reductions. `interpret=True` everywhere in this image (CPU PJRT only); the
real-TPU resource estimate lives in `vmem_bytes()` / DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import sample_tile


def _moments_kernel(x_ref, m_ref, n_ref, sx_ref, sxx_ref):
    """One grid step: accumulate moments of a (D, Tn) sample tile."""
    step = pl.program_id(0)

    # The output blocks have a constant index_map, so the same VMEM buffers
    # are revisited every step: zero them on the first visit.
    @pl.when(step == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)
        sxx_ref[...] = jnp.zeros_like(sxx_ref)

    x = x_ref[...]                    # (D, Tn)
    msk = m_ref[...]                  # (1, Tn)
    xm = x * msk                      # masked samples

    n_ref[...] += jnp.sum(msk, keepdims=True).reshape(n_ref.shape)
    sx_ref[...] += jnp.sum(xm, axis=1, keepdims=True)
    # rank-Tn update; MXU-shaped contraction over the sample axis
    sxx_ref[...] += jax.lax.dot_general(
        xm, x, (((1,), (1,)), ((), ())),
        preferred_element_type=sxx_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("tile",))
def moments(x: jnp.ndarray, mask: jnp.ndarray, *, tile: int | None = None):
    """Masked raw moments via the Pallas kernel.

    Args:
      x: (D, N) sample block, one sample per column.
      mask: (N,) 0/1 sample-validity mask (float dtype matching ``x``).
      tile: sample-axis tile size; defaults to ``shapes.sample_tile(N)``.

    Returns:
      (n, sx, sxx) with shapes () , (D,), (D, D).
    """
    d, n_cols = x.shape
    tn = tile if tile is not None else sample_tile(n_cols)
    if n_cols % tn != 0:
        raise ValueError(f"N={n_cols} not a multiple of tile {tn}")
    grid = (n_cols // tn,)
    mask2 = mask.reshape(1, n_cols)

    n_out, sx_out, sxx_out = pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, tn), lambda i: (0, i)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), x.dtype),
            jax.ShapeDtypeStruct((d, 1), x.dtype),
            jax.ShapeDtypeStruct((d, d), x.dtype),
        ],
        interpret=True,  # CPU PJRT only — see module docstring
    )(x, mask2)
    return n_out[0, 0], sx_out[:, 0], sxx_out


def vmem_bytes(d: int, tile: int, itemsize: int = 8) -> int:
    """Estimated VMEM residency of one grid step on a real TPU.

    Stationary outputs (n, sx, sxx) + one streamed X tile + mask tile,
    double-buffered on the streamed operands.
    """
    stationary = (1 + d + d * d) * itemsize
    streamed = 2 * (d * tile + tile) * itemsize  # ×2: double buffering
    return stationary + streamed
