"""L1 Pallas kernel: batched posterior E-step.

Computes E[z_k] = M⁻¹Wᵀ(x_k − μ) for every masked sample. Used (a) inside
the direct per-iteration update path and (b) once at the end of a run to
extract the latent representation (the reconstructed 3-D structure in the
SfM experiments).

The tiny M×M system inverse is computed *outside* the kernel (it is
O(M³) with M ∈ {2,3,5}); the kernel streams X in (D × Tn) column tiles and
performs the two MXU contractions per tile with W and (M⁻¹Wᵀ) stationary
in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import sample_tile
from ..smallinv import inv_spd


def _estep_kernel(pw_ref, mu_ref, x_ref, m_ref, z_ref):
    """One grid step: z-tile = PW (x-tile − μ) with masking."""
    pw = pw_ref[...]                  # (M, D) = M⁻¹Wᵀ, stationary
    mu = mu_ref[...]                  # (D, 1), stationary
    x = x_ref[...]                    # (D, Tn), streamed
    msk = m_ref[...]                  # (1, Tn), streamed
    centred = (x - mu) * msk
    z_ref[...] = jax.lax.dot_general(
        pw, centred, (((1,), (0,)), ((), ())),
        preferred_element_type=z_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("tile",))
def estep_z(x: jnp.ndarray, mask: jnp.ndarray, w: jnp.ndarray,
            mu: jnp.ndarray, a: jnp.ndarray, *, tile: int | None = None):
    """Posterior means for every sample column of ``x``.

    Args:
      x: (D, N) samples; mask: (N,); w: (D, M); mu: (D,); a: scalar noise
      precision.

    Returns:
      (M, N) posterior means, zero in masked-out columns.
    """
    d, n_cols = x.shape
    m = w.shape[1]
    tn = tile if tile is not None else sample_tile(n_cols)
    if n_cols % tn != 0:
        raise ValueError(f"N={n_cols} not a multiple of tile {tn}")

    mmat = w.T @ w + jnp.eye(m, dtype=x.dtype) / a
    minv = inv_spd(mmat)
    pw = minv @ w.T                   # (M, D)

    z = pl.pallas_call(
        _estep_kernel,
        grid=(n_cols // tn,),
        in_specs=[
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, tn), lambda i: (0, i)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n_cols), x.dtype),
        interpret=True,  # CPU PJRT only
    )(pw, mu.reshape(d, 1), x, mask.reshape(1, n_cols))
    return z
