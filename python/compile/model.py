"""L2: the D-PPCA node computation in JAX.

One EM + consensus-ADMM iteration of a single node, expressed over the
masked raw moments (n, sx, Sxx) — see DESIGN.md §1 for the algebra and the
paper (eq. 15) for the μ-update template the W/a updates are derived from.
The per-edge penalties enter only through four aggregates the Rust
coordinator computes in O(deg) per iteration:

  eta_sum  = Σ_j η_ij                      (scalar)
  eta_w_w  = Σ_j η_ij (W_i + W_j)          (D, M)
  eta_w_mu = Σ_j η_ij (μ_i + μ_j)          (D,)
  eta_w_a  = Σ_j η_ij (a_i + a_j)          (scalar)

so a single lowered artifact serves any topology / penalty scheme / degree.

Functions here are lowered once by `aot.py`; nothing in this file runs at
optimization time.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.moments import moments
from .smallinv import inv_and_logdet_spd

_LOG_2PI = 1.8378770664093453  # log(2π)


def centred_scatter(n, sx, sxx, mu):
    """S(μ) = Σ m_k (x_k − μ)(x_k − μ)ᵀ from raw moments."""
    return sxx - jnp.outer(sx, mu) - jnp.outer(mu, sx) + n * jnp.outer(mu, mu)


def marginal_nll(n, sx, sxx, w, mu, a):
    """Marginal PPCA negative log-likelihood −log p(X | W, μ, a).

    C = WWᵀ + a⁻¹I handled in M×M space:
      log|C| = (M−D)·log a + log|M|,   tr(C⁻¹S) = a·(tr S − tr(M⁻¹ WᵀSW)).
    """
    d, m = w.shape
    mmat = w.T @ w + jnp.eye(m, dtype=w.dtype) / a
    minv, logdet_m = inv_and_logdet_spd(mmat)
    s = centred_scatter(n, sx, sxx, mu)
    wtsw = w.T @ s @ w
    tr_term = a * (jnp.trace(s) - jnp.sum(minv * wtsw))
    logdet_c = (m - d) * jnp.log(a) + logdet_m
    return 0.5 * (n * d * _LOG_2PI + n * logdet_c + tr_term)


def node_update_from_moments(n, sx, sxx, w, mu, a, lam, gam, beta,
                             eta_sum, eta_w_w, eta_w_mu, eta_w_a):
    """One E-step + consensus M-step + objective evaluation.

    Args mirror the artifact calling convention (see aot.py / the Rust
    `runtime::convention` module):
      n, sx, sxx                      masked moments of the local data
      w (D,M), mu (D,), a ()          current local parameters
      lam (D,M), gam (D,), beta ()    Lagrange multipliers
      eta_*                           consensus aggregates (module docstring)

    Returns:
      (w_new, mu_new, a_new, nll_new)
    """
    d, m = w.shape
    eye_m = jnp.eye(m, dtype=w.dtype)

    # ---- E-step (old parameters), aggregate form --------------------------
    minv, _ = inv_and_logdet_spd(w.T @ w + eye_m / a)
    s_old = centred_scatter(n, sx, sxx, mu)
    cxz = s_old @ w @ minv                     # Σ (x−μ)E[z]ᵀ          (D,M)
    wtssw = w.T @ s_old @ w
    ezz_sum = n / a * minv + minv @ wtssw @ minv  # Σ E[zzᵀ]           (M,M)
    sz = minv @ (w.T @ (sx - n * mu))          # Σ E[z]                (M,)

    # ---- W update ---------------------------------------------------------
    numer_w = a * cxz - 2.0 * lam + eta_w_w
    denom_w = a * ezz_sum + 2.0 * eta_sum * eye_m
    denom_w_inv, _ = inv_and_logdet_spd(denom_w)
    w_new = numer_w @ denom_w_inv

    # ---- μ update (uses fresh W; paper eq. 15) ----------------------------
    numer_mu = a * (sx - w_new @ sz) - 2.0 * gam + eta_w_mu
    mu_new = numer_mu / (n * a + 2.0 * eta_sum)

    # ---- a update: positive root of  A·a² + B·a − C = 0 -------------------
    s_new = centred_scatter(n, sx, sxx, mu_new)
    cxz_new = cxz + jnp.outer(mu - mu_new, sz)  # Σ (x−μ_new)E[z]ᵀ
    c_sum = (jnp.trace(s_new)
             - 2.0 * jnp.sum(w_new * cxz_new)
             + jnp.sum((w_new.T @ w_new) * ezz_sum))
    a_coef = 2.0 * eta_sum
    b_coef = 2.0 * beta + 0.5 * c_sum - eta_w_a
    c_coef = n * d / 2.0
    # consensus case: positive quadratic root; centralized (η≡0): C/B
    disc = jnp.sqrt(b_coef * b_coef + 4.0 * a_coef * c_coef)
    a_new = jnp.where(a_coef > 1e-12,
                      (disc - b_coef) / jnp.where(a_coef > 1e-12, 2.0 * a_coef, 1.0),
                      c_coef / b_coef)

    nll_new = marginal_nll(n, sx, sxx, w_new, mu_new, a_new)
    return w_new, mu_new, a_new, nll_new


def node_update_direct(x, mask, w, mu, a, lam, gam, beta,
                       eta_sum, eta_w_w, eta_w_mu, eta_w_a):
    """Direct path: full pass over the raw data every iteration.

    Identical numbers to `node_update_from_moments` (asserted in pytest);
    this is the faithful per-iteration cost model of the paper, with the
    Pallas moments kernel on the hot path.
    """
    n, sx, sxx = moments(x, mask)
    return node_update_from_moments(n, sx, sxx, w, mu, a, lam, gam, beta,
                                    eta_sum, eta_w_w, eta_w_mu, eta_w_a)


def objective_from_moments(n, sx, sxx, w, mu, a):
    """Artifact wrapper: marginal NLL of (possibly foreign) parameters.

    Used by the AP/NAP penalty schemes, which evaluate the *local* objective
    f_i at the neighbours' parameter estimates (paper eq. 7–8).
    """
    return marginal_nll(n, sx, sxx, w, mu, a)


#: batch width of the `objective_batch` artifact (≥ max node degree of any
#: experiment topology; unused slots are padded with copies — see the Rust
#: runtime). One PJRT dispatch then serves a node's whole neighbourhood,
#: which is the dominant §Perf win for the AP/NAP schemes.
OBJECTIVE_BATCH = 20


def objective_batch_from_moments(n, sx, sxx, ws, mus, a_s):
    """Vmapped marginal NLL: score `OBJECTIVE_BATCH` parameter sets against
    one node's moments in a single executable.

    Args: ws (B, D, M), mus (B, D), a_s (B,) → (B,) NLL values.
    """
    import jax

    return jax.vmap(marginal_nll, in_axes=(None, None, None, 0, 0, 0))(
        n, sx, sxx, ws, mus, a_s)
