"""Shape registry for AOT lowering.

Every (D, M, N) combination used by the Rust experiment harness is declared
here; `aot.py` lowers one HLO artifact per (kind, shape) pair. D is the
observation dimension, M the latent dimension, N the padded per-node sample
count (actual sample counts are carried by a 0/1 mask so one artifact serves
every node of an experiment).

Keep this list in sync with `rust/src/experiments/*.rs` (the Rust side
fails loudly at startup if a required artifact is missing from the
manifest, so drift is caught immediately).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeConfig:
    """One lowering target: D×N data with an M-dimensional latent space."""

    d: int  # observation dimension
    m: int  # latent dimension
    n: int  # padded per-node sample budget

    @property
    def dm(self) -> tuple[int, int]:
        return (self.d, self.m)

    @property
    def dn(self) -> tuple[int, int]:
        return (self.d, self.n)


#: All experiment shapes. See DESIGN.md §6.
CONFIGS: list[ShapeConfig] = [
    # tests / quickstart
    ShapeConfig(d=8, m=2, n=16),
    # E1/E2 (Fig. 2): 500 samples of dim 20, M=5, split over J nodes
    ShapeConfig(d=20, m=5, n=25),  # J = 20
    ShapeConfig(d=20, m=5, n=32),  # J = 16 (500/16 = 31.25 -> mask-padded)
    ShapeConfig(d=20, m=5, n=42),  # J = 12 (500/12 = 41.67 -> mask-padded)
    # E3 (Fig. 3/5): turntable SfM, 120 tracked points, 30 frames, 5 cameras
    # transposed measurement matrix: D = #points, samples = 2F_i = 12
    ShapeConfig(d=120, m=3, n=12),
    # E4 (Hopkins-like corpus): bucketed object sizes
    ShapeConfig(d=60, m=3, n=6),
    ShapeConfig(d=60, m=3, n=12),
    ShapeConfig(d=100, m=3, n=6),
    ShapeConfig(d=100, m=3, n=12),
    ShapeConfig(d=140, m=3, n=6),
    ShapeConfig(d=140, m=3, n=12),
]


def sample_tile(n: int) -> int:
    """Pallas tile size along the sample axis.

    Small paddings are a single tile; large ones stream 128-wide column
    tiles (N is required to be a multiple of the tile).
    """
    if n <= 256:
        return n
    if n % 128 != 0:
        raise ValueError(f"large sample budgets must be multiples of 128, got {n}")
    return 128


def unique_dm() -> list[tuple[int, int]]:
    seen: dict[tuple[int, int], None] = {}
    for c in CONFIGS:
        seen.setdefault(c.dm)
    return list(seen)


def unique_dn() -> list[tuple[int, int]]:
    seen: dict[tuple[int, int], None] = {}
    for c in CONFIGS:
        seen.setdefault(c.dn)
    return list(seen)
