"""L2 model math: EM monotonicity, consensus fixed points, oracle parity.

The strongest test is `test_matches_tipping_bishop_optimum`: centralized EM
run through `node_update_from_moments` (all consensus terms zero) must
converge to the analytic PPCA maximum-likelihood solution.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import moments_ref
from compile.smallinv import inv_and_logdet_spd


def _zeros_consensus(d, m):
    return (jnp.zeros((d, m)), jnp.zeros(d), jnp.asarray(0.0),
            jnp.asarray(0.0), jnp.zeros((d, m)), jnp.zeros(d),
            jnp.asarray(0.0))


def _run_centralized_em(x, m, iters=200, seed=0):
    d, _ = x.shape
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(d, m)))
    mu = jnp.asarray(rng.normal(size=d))
    a = jnp.asarray(1.0)
    n, sx, sxx = moments_ref(x, jnp.ones(x.shape[1]))
    lam, gam, beta, es, eww, ewmu, ewa = _zeros_consensus(d, m)

    def body(_, carry):
        w, mu, a, _ = carry
        return model.node_update_from_moments(
            n, sx, sxx, w, mu, a, lam, gam, beta, es, eww, ewmu, ewa)

    w, mu, a, nll = jax.jit(
        lambda c: jax.lax.fori_loop(0, iters, body, c)
    )((w, mu, a, jnp.asarray(0.0)))
    return w, mu, a, float(nll)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 4), seed=st.integers(0, 10_000))
def test_smallinv_matches_numpy(m, seed):
    rng = np.random.default_rng(seed)
    b = rng.normal(size=(m, m))
    spd = b @ b.T + m * np.eye(m)
    inv, logdet = inv_and_logdet_spd(jnp.asarray(spd))
    np.testing.assert_allclose(np.asarray(inv), np.linalg.inv(spd), rtol=1e-9)
    np.testing.assert_allclose(float(logdet), np.linalg.slogdet(spd)[1],
                               rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_centralized_em_monotone(seed):
    rng = np.random.default_rng(seed)
    d, m, n = 10, 3, 60
    x = jnp.asarray(rng.normal(size=(d, n)))
    nmom, sx, sxx = moments_ref(x, jnp.ones(n))
    lam, gam, beta, es, eww, ewmu, ewa = _zeros_consensus(d, m)
    w = jnp.asarray(rng.normal(size=(d, m)))
    mu = jnp.asarray(rng.normal(size=d))
    a = jnp.asarray(1.0)
    prev = float(model.marginal_nll(nmom, sx, sxx, w, mu, a))
    for _ in range(40):
        w, mu, a, nll = model.node_update_from_moments(
            nmom, sx, sxx, w, mu, a, lam, gam, beta, es, eww, ewmu, ewa)
        assert float(nll) <= prev + 1e-7
        prev = float(nll)


def test_matches_tipping_bishop_optimum():
    """EM must reach the analytic PPCA ML solution (Tipping & Bishop '99).

    ML: μ* = sample mean; σ²* = mean of discarded eigenvalues of sample
    covariance; NLL* computable in closed form from the eigenvalues.
    """
    rng = np.random.default_rng(42)
    d, m, n = 12, 4, 400
    w_true = rng.normal(size=(d, m))
    z = rng.normal(size=(m, n))
    x = w_true @ z + rng.normal(size=(d, 1)) + 0.3 * rng.normal(size=(d, n))

    # the μ-update contracts toward the sample mean with factor
    # λ/(λ + a⁻¹) ≈ 0.99 per sweep, so give EM room to converge fully
    w, mu, a, nll = _run_centralized_em(jnp.asarray(x), m, iters=6000)

    xbar = x.mean(axis=1)
    np.testing.assert_allclose(np.asarray(mu), xbar, atol=1e-6)

    s = np.cov(x, bias=True)
    evals = np.sort(np.linalg.eigvalsh(s))[::-1]
    sigma2_star = evals[m:].mean()
    np.testing.assert_allclose(1.0 / float(a), sigma2_star, rtol=1e-5)

    # analytic optimal NLL
    ll_terms = d * np.log(2 * np.pi) + np.sum(np.log(evals[:m])) \
        + (d - m) * np.log(sigma2_star) + m + (d - m)
    nll_star = 0.5 * n * ll_terms
    np.testing.assert_allclose(nll, nll_star, rtol=1e-8)


def test_direct_equals_moments_path():
    rng = np.random.default_rng(7)
    d, m, n = 8, 2, 16
    x = jnp.asarray(rng.normal(size=(d, n)))
    mask = jnp.asarray((rng.random(n) < 0.7).astype(np.float64))
    w = jnp.asarray(rng.normal(size=(d, m)))
    mu = jnp.asarray(rng.normal(size=d))
    a = jnp.asarray(1.5)
    lam = jnp.asarray(rng.normal(size=(d, m)) * 0.1)
    gam = jnp.asarray(rng.normal(size=d) * 0.1)
    beta = jnp.asarray(0.05)
    es = jnp.asarray(20.0)
    eww = jnp.asarray(rng.normal(size=(d, m)))
    ewmu = jnp.asarray(rng.normal(size=d))
    ewa = jnp.asarray(30.0)
    nmom, sx, sxx = moments_ref(x, mask)
    a_out = model.node_update_from_moments(nmom, sx, sxx, w, mu, a, lam, gam,
                                           beta, es, eww, ewmu, ewa)
    b_out = model.node_update_direct(x, mask, w, mu, a, lam, gam, beta, es,
                                     eww, ewmu, ewa)
    for p, q in zip(a_out, b_out):
        np.testing.assert_allclose(np.asarray(p), np.asarray(q), rtol=1e-11)


def test_consensus_terms_pull_parameters():
    """With a huge penalty toward a target, W must move toward it."""
    rng = np.random.default_rng(3)
    d, m, n = 6, 2, 40
    x = jnp.asarray(rng.normal(size=(d, n)))
    nmom, sx, sxx = moments_ref(x, jnp.ones(n))
    w = jnp.asarray(rng.normal(size=(d, m)))
    w_target = jnp.asarray(rng.normal(size=(d, m)))
    mu = jnp.asarray(rng.normal(size=d))
    a = jnp.asarray(1.0)
    eta = 1e7
    # one neighbour with parameters w_target: Ση(W_i+W_j) = η(w + w_target)
    w_new, _, _, _ = model.node_update_from_moments(
        nmom, sx, sxx, w, mu, a,
        jnp.zeros((d, m)), jnp.zeros(d), jnp.asarray(0.0),
        jnp.asarray(eta), eta * (w + w_target),
        eta * (mu + mu), jnp.asarray(eta * 2.0))
    np.testing.assert_allclose(np.asarray(w_new),
                               np.asarray((w + w_target) / 2), atol=1e-4)


def test_a_update_positive():
    """The noise precision stays positive under adversarial multipliers."""
    rng = np.random.default_rng(9)
    d, m, n = 5, 2, 30
    x = jnp.asarray(rng.normal(size=(d, n)))
    nmom, sx, sxx = moments_ref(x, jnp.ones(n))
    for beta_v in (-50.0, 0.0, 50.0):
        _, _, a_new, _ = model.node_update_from_moments(
            nmom, sx, sxx, jnp.asarray(rng.normal(size=(d, m))),
            jnp.asarray(rng.normal(size=d)), jnp.asarray(1.0),
            jnp.zeros((d, m)), jnp.zeros(d), jnp.asarray(beta_v),
            jnp.asarray(10.0), jnp.zeros((d, m)), jnp.zeros(d),
            jnp.asarray(25.0))
        assert float(a_new) > 0.0


def test_marginal_nll_matches_dense_gaussian():
    """Woodbury NLL equals the dense multivariate-normal evaluation."""
    rng = np.random.default_rng(11)
    d, m, n = 7, 3, 25
    x = rng.normal(size=(d, n))
    w = rng.normal(size=(d, m))
    mu = rng.normal(size=d)
    a = 2.5
    nmom, sx, sxx = moments_ref(jnp.asarray(x), jnp.ones(n))
    got = float(model.marginal_nll(nmom, sx, sxx, jnp.asarray(w),
                                   jnp.asarray(mu), jnp.asarray(a)))
    c = w @ w.T + np.eye(d) / a
    xc = x - mu[:, None]
    want = 0.5 * (n * d * np.log(2 * np.pi) + n * np.linalg.slogdet(c)[1]
                  + np.trace(np.linalg.solve(c, xc @ xc.T)))
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_objective_batch_matches_scalar():
    """The vmapped batch artifact must equal per-item marginal NLL."""
    rng = np.random.default_rng(13)
    d, m, n = 8, 2, 20
    x = jnp.asarray(rng.normal(size=(d, n)))
    nmom, sx, sxx = moments_ref(x, jnp.ones(n))
    b = model.OBJECTIVE_BATCH
    ws = jnp.asarray(rng.normal(size=(b, d, m)))
    mus = jnp.asarray(rng.normal(size=(b, d)))
    a_s = jnp.asarray(rng.uniform(0.2, 5.0, size=b))
    batched = model.objective_batch_from_moments(nmom, sx, sxx, ws, mus, a_s)
    for k in range(b):
        want = float(model.marginal_nll(nmom, sx, sxx, ws[k], mus[k], a_s[k]))
        np.testing.assert_allclose(float(batched[k]), want, rtol=1e-11)
