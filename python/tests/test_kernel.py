"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes, tile sizes and mask patterns; fixed
seeds keep the suite deterministic.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.estep import estep_z
from compile.kernels.moments import moments, vmem_bytes
from compile.kernels.ref import estep_z_ref, moments_ref

DTYPES = [np.float32, np.float64]


def _data(seed, d, n, dtype, mask_p=0.8, scale=1.0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(d, n)) * scale, dtype=dtype)
    mask = jnp.asarray((rng.random(n) < mask_p).astype(dtype))
    return x, mask


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(1, 40),
    tiles=st.integers(1, 4),
    tile=st.sampled_from([1, 2, 8, 16, 128]),
    dtype_i=st.integers(0, 1),
    mask_p=st.floats(0.0, 1.0),
)
def test_moments_matches_ref(seed, d, tiles, tile, dtype_i, mask_p):
    dtype = DTYPES[dtype_i]
    n = tiles * tile
    x, mask = _data(seed, d, n, dtype, mask_p)
    got = moments(x, mask, tile=tile)
    want = moments_ref(x, mask)
    rtol = 1e-5 if dtype == np.float32 else 1e-12
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=rtol, atol=rtol)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(2, 30),
    m=st.integers(1, 5),
    tiles=st.integers(1, 3),
    tile=st.sampled_from([2, 8, 16]),
    a=st.floats(0.1, 50.0),
)
def test_estep_matches_ref(seed, d, m, tiles, tile, a):
    m = min(m, d)
    n = tiles * tile
    rng = np.random.default_rng(seed)
    x, mask = _data(seed, d, n, np.float64)
    w = jnp.asarray(rng.normal(size=(d, m)))
    mu = jnp.asarray(rng.normal(size=d))
    got = estep_z(x, mask, w, mu, jnp.asarray(a), tile=tile)
    want = estep_z_ref(x, mask, w, mu, jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)


def test_moments_empty_mask():
    """All samples masked out → exact zeros, no NaN."""
    x, _ = _data(1, 6, 8, np.float64)
    mask = jnp.zeros(8)
    n, sx, sxx = moments(x, mask)
    assert float(n) == 0.0
    assert np.all(np.asarray(sx) == 0.0) and np.all(np.asarray(sxx) == 0.0)


def test_moments_full_mask_equals_unmasked_gram():
    x, _ = _data(2, 5, 12, np.float64)
    mask = jnp.ones(12)
    n, sx, sxx = moments(x, mask)
    assert float(n) == 12.0
    np.testing.assert_allclose(np.asarray(sxx), np.asarray(x @ x.T), rtol=1e-12)


def test_moments_tile_invariance():
    """Same result regardless of how the sample axis is tiled."""
    x, mask = _data(3, 10, 32, np.float64)
    base = moments(x, mask, tile=32)
    for tile in (1, 2, 4, 8, 16):
        got = moments(x, mask, tile=tile)
        for g, b in zip(got, base):
            np.testing.assert_allclose(np.asarray(g), np.asarray(b), rtol=1e-12)


def test_moments_rejects_bad_tile():
    x, mask = _data(4, 4, 10, np.float64)
    with pytest.raises(ValueError):
        moments(x, mask, tile=4)


def test_estep_masked_columns_zero():
    x, _ = _data(5, 7, 9, np.float64)
    mask = jnp.asarray(np.array([1, 0, 1, 0, 0, 1, 1, 0, 1], dtype=np.float64))
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(7, 3)))
    z = np.asarray(estep_z(x, mask, w, jnp.zeros(7), jnp.asarray(1.0), tile=9))
    assert np.all(z[:, np.asarray(mask) == 0] == 0.0)


def test_vmem_estimate_within_tpu_budget():
    """DESIGN.md §Perf: every declared shape fits a 16 MiB VMEM budget."""
    from compile.shapes import CONFIGS, sample_tile

    for cfg in CONFIGS:
        b = vmem_bytes(cfg.d, sample_tile(cfg.n))
        assert b < 16 * 2**20, (cfg, b)
