"""AOT pipeline: lowering plan, HLO hygiene, manifest schema.

The critical invariant is *no custom-calls*: `jnp.linalg.*` on CPU lowers
to LAPACK custom-calls that the pinned xla_extension 0.5.1 runtime behind
the Rust `xla` crate cannot execute. Every artifact must be plain HLO.
"""

import json

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot
from compile.shapes import CONFIGS, sample_tile, unique_dm, unique_dn


def test_plan_covers_every_config():
    plan = {name: meta for name, _, _, meta in aot.build_plan()}
    for d, m in unique_dm():
        assert f"node_update_d{d}_m{m}" in plan
        assert f"objective_d{d}_m{m}" in plan
        assert f"objective_batch_d{d}_m{m}" in plan
    for d, n in unique_dn():
        assert f"moments_d{d}_n{n}" in plan
    for cfg in CONFIGS:
        assert f"node_update_direct_d{cfg.d}_m{cfg.m}_n{cfg.n}" in plan
        assert f"estep_z_d{cfg.d}_m{cfg.m}_n{cfg.n}" in plan


def test_plan_names_unique():
    names = [name for name, *_ in aot.build_plan()]
    assert len(names) == len(set(names))


def test_sample_tile_contract():
    assert sample_tile(16) == 16
    assert sample_tile(256) == 256
    assert sample_tile(512) == 128
    with pytest.raises(ValueError):
        sample_tile(300)


@pytest.mark.parametrize("name", ["node_update_d8_m2", "moments_d8_n16",
                                  "node_update_direct_d8_m2_n16",
                                  "estep_z_d8_m2_n16", "objective_d8_m2"])
def test_lowering_is_custom_call_free(name):
    plan = {n: (fn, specs) for n, fn, specs, _ in aot.build_plan()}
    fn, specs = plan[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "custom-call" not in text, f"{name} contains a custom-call"
    assert text.startswith("HloModule")


def test_manifest_written(tmp_path):
    """Full manifest round-trip on the smallest config subset."""
    # monkeypatch the plan down to the d8 artifacts to keep the test fast
    small = [p for p in aot.build_plan() if "_d8_" in p[0] or p[0].endswith("d8_m2")]
    orig = aot.build_plan
    aot.build_plan = lambda: small
    try:
        manifest = aot.lower_all(str(tmp_path), verbose=False)
    finally:
        aot.build_plan = orig
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == manifest
    assert on_disk["dtype"] == "f64"
    for e in on_disk["artifacts"]:
        assert (tmp_path / e["file"]).exists()
        assert e["num_inputs"] == len(e["input_shapes"])
        assert e["kind"] in {"node_update", "node_update_direct", "moments",
                             "objective", "objective_batch", "estep_z"}
